//! Tensor arena for the real-numerics path — a memory-model note.
//!
//! # Layout
//!
//! All of a graph's tensors live in **one contiguous `f32` slab** with a
//! per-tensor offset table ([`TensorStore::new`] packs them in id
//! order). Tensors may instead be *aliased* into a [`SharedSlab`] owned
//! outside the store ([`TensorStore::new_with_aliases`]) — the serving
//! engine uses this twice: every batch-size-specialized session's KV
//! cache tensors point at one shared max-batch KV arena (so a request's
//! cache rows never move when the engine switches specializations), and
//! every session's **parameter tensors** point at one shared weight
//! arena (`exec::real::WeightArena`), initialized once and read-only
//! thereafter.
//!
//! # Who may read or write, and when
//!
//! There are **no per-access locks**. Synchronization is inherited from
//! the compiled graph: the MPK compiler introduces an event edge between
//! two tasks whenever a producer's output region overlaps a consumer's
//! input region (§4.1), and the in-kernel runtime only launches a task
//! once every dependent event has activated (§5). Event activation uses
//! acquire/release atomics, so a writer's stores happen-before every
//! reader that the graph orders after it. The aliasing contract is
//! therefore:
//!
//! * A region may be written by at most one in-flight task; concurrent
//!   tasks writing the same tensor must write **disjoint** regions
//!   (operator decomposition partitions outputs into disjoint tiles).
//! * A region may be read concurrently by any number of tasks, provided
//!   no in-flight task writes an overlapping region. The event graph's
//!   writer-before-reader edges establish exactly this.
//! * Host-side staging (weight init, per-iteration token ids, logits
//!   harvest, KV slot remaps) runs only while the kernel is quiesced —
//!   the persistent kernel's `run()` does not return mid-epoch, so the
//!   single-threaded engine loop never races the workers.
//! * **Read-only cross-session aliasing (the weight arena).** A tensor
//!   aliased into a shared slab by *several* stores at once is sound
//!   under a stricter discipline than the per-graph event order, which
//!   only sequences tasks of one compiled graph: the region must be
//!   written only before any aliasing kernel first runs, and never
//!   again (re-initialization while another session's kernel is
//!   mid-epoch would race). The serving engine's weight arena obeys
//!   this by construction — weights are synthesized once at engine
//!   `create`, before any session kernel has executed, and no
//!   compiled-graph task ever has a param tensor as its output — so
//!   concurrent reads from different sessions need no ordering at all.
//!   The shared max-batch KV arena is the *mutable* counterpart; it
//!   stays sound because the engine runs one session's kernel at a
//!   time and slots are stable (no two sessions' tasks are ever in
//!   flight together, and slot ownership never changes while a request
//!   lives).
//!
//! * **Block aliasing (the paged KV pool).** With paging on
//!   (`serving/paged.rs`), several requests' block tables may map the
//!   *same* physical block of the KV slab — a shared prompt prefix.
//!   The rule is: **a shared block (refcount > 1) is read-only until
//!   copy-on-write.** Appends are re-pointed at a private copy by the
//!   engine's pre-epoch `ensure_append` pass, which runs while the
//!   kernel is quiesced, so by the time any task is in flight every
//!   row a `KvAppend` will write lives in a block with exactly one
//!   referencing table and concurrent readers of the shared original
//!   race with nothing. Reads of shared blocks need no ordering beyond
//!   the usual writer-before-reader event edges because no in-flight
//!   task ever writes them ([`SharedSlab::view_span`] is the read
//!   primitive; the COW copy itself is a quiesced-host
//!   [`SharedSlab::copy_within`], honestly counted by the engine as
//!   `kv_blocks_cowed`, never by the store's counters).
//!
//! * **Mutable views (pool output destinations).** A task that owns an
//!   output region may borrow it mutably ([`TensorStore::view_region_mut`],
//!   [`TensorStore::tile_mut`] / [`TileViewMut`]) and hand it to the
//!   PJRT pool as an [`OutView`] destination (`ExecPool::execute_into`):
//!   the executor thread then writes the result straight into the
//!   arena while the task's worker is parked in the call — the worker's
//!   exclusive borrow spans the whole call, so the executor is the
//!   region's only writer, and the event graph already guarantees no
//!   other task reads or writes an overlapping region while this task
//!   is in flight (same writer-before-reader argument as above, with
//!   the executor thread acting *as* the task). A mutable view of a
//!   region is a **write** for the purposes of the contract whether or
//!   not anything is ultimately stored through it.
//!
//! Under that contract, borrowed views ([`TensorStore::view`],
//! [`TileView`], [`TileViewMut`]) are sound: every `unsafe` block in
//! this module reduces to "reads and writes that the event graph orders
//! or keeps disjoint", and the raw-pointer slab means disjoint
//! concurrent accesses touch disjoint memory locations — no Rust
//! reference is ever constructed over a region another thread may
//! mutate.
//!
//! This module is the **only** place allowed to dereference the slab;
//! keep every `unsafe` here so it stays auditable (the tier-1 script
//! runs `cargo miri test` over this module when miri is installed).
//! The crate's full audited unsafe surface is this arena plus three
//! satellites — the pool's lifetime-erased channel crossing
//! (`runtime/pool.rs`), the megakernel's MPMC task queue
//! (`megakernel/queue.rs`), and its scoped executor borrow
//! (`megakernel/runtime.rs`) — and the tier-1 script's grep lint fails
//! the build if `unsafe` appears anywhere else; the crate root denies
//! `unsafe_op_in_unsafe_fn` so every raw operation sits in an explicit
//! inner `unsafe {}` block next to its SAFETY comment.
//!
//! The "event graph orders or keeps disjoint" premise itself is no
//! longer taken on faith: [`crate::tgraph::verify`] statically
//! re-derives every task's read/write footprint from the operator
//! semantics and checks that each overlapping writer/reader and
//! writer/writer pair is connected by a happens-before path in the
//! compiled task/event DAG (plus acyclicity/liveness, per-stage
//! relation preservation, and mutation-tested analyzer soundness).
//! That verifier is the machine-checked half of this aliasing
//! contract: the static half proves the orderings exist, the unsafe
//! code here relies on the runtime delivering them. It runs as a
//! compile gate (`CompileOptions::verify`, on by default in debug) and
//! as `mpk verify` in CI.
//!
//! # Debug assertions
//!
//! In debug builds every tile-granular operation registers its region
//! in an in-flight table for the duration of the call (and for the
//! lifetime of a [`TileView`] or [`TileViewMut`]); a write overlapping
//! any in-flight access, or any access overlapping an in-flight write,
//! panics with both regions. Whole-tensor [`TensorStore::view`] borrows
//! are deliberately untracked, and the slices returned by
//! [`TensorStore::view_region`] / [`TensorStore::view_region_mut`] are
//! tracked only for the duration of the call that creates them — their
//! soundness past that point is the event graph's responsibility — so
//! the checker is a race *detector* for the tiled hot path, not a
//! proof. Task bodies that hold an output destination across a pool
//! call use [`TileViewMut`], whose write registration spans the whole
//! call.
//!
//! # Counters
//!
//! The store counts read-side materializations: `allocs` (fresh `Vec`
//! returned by [`TensorStore::get`] / [`TensorStore::read_tile`]) and
//! `bytes_copied` (those reads plus [`TensorStore::copy_tile_from`]
//! migrations). Writes that land results in the arena (`set`,
//! `write_tile`, mutable views) are not copies *of* a tensor and are
//! not counted; output buffers allocated at the pool boundary are
//! counted separately by `ExecPool::output_allocs`. The borrowed-view
//! hot path keeps all of them at zero — asserted by
//! `benches/hotpath_micro.rs` and the steady-state serving tests.

use crate::ops::{CompGraph, Region, TensorId};
use crate::runtime::pool::OutView;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

#[cfg(debug_assertions)]
use std::sync::Mutex;

/// Maximum tensor rank the run walker supports (stack-allocated state —
/// the tile hot path performs no heap allocation).
const MAX_RANK: usize = 8;

/// A raw `f32` slab. All access goes through the pointer; no Rust
/// reference to the whole buffer is ever created after construction, so
/// disjoint concurrent reads/writes are data-race-free plain memory
/// operations.
struct ArenaBuf {
    ptr: *mut f32,
    len: usize,
}

impl ArenaBuf {
    fn new(len: usize) -> ArenaBuf {
        let boxed: Box<[f32]> = vec![0.0f32; len].into_boxed_slice();
        ArenaBuf { ptr: Box::into_raw(boxed) as *mut f32, len }
    }
}

impl Drop for ArenaBuf {
    fn drop(&mut self) {
        // SAFETY: `ptr`/`len` came from `Box::into_raw` of a boxed slice
        // of exactly `len` elements and are dropped exactly once.
        unsafe {
            drop(Box::from_raw(std::ptr::slice_from_raw_parts_mut(self.ptr, self.len)));
        }
    }
}

// SAFETY: the slab is plain `f32` storage; all mutation goes through raw
// pointers under the aliasing contract in the module doc.
unsafe impl Send for ArenaBuf {}
unsafe impl Sync for ArenaBuf {}

/// A reference-counted slab shared between stores — the backing memory
/// of the serving engine's max-batch KV arena. Cloning the handle
/// aliases the same memory.
#[derive(Clone)]
pub struct SharedSlab {
    buf: Arc<ArenaBuf>,
}

impl SharedSlab {
    /// Zero-initialized shared slab of `len` f32 elements.
    pub fn new(len: usize) -> SharedSlab {
        SharedSlab { buf: Arc::new(ArenaBuf::new(len)) }
    }

    pub fn len(&self) -> usize {
        self.buf.len
    }

    pub fn is_empty(&self) -> bool {
        self.buf.len == 0
    }

    /// True if both handles alias the same memory.
    pub fn same_slab(&self, other: &SharedSlab) -> bool {
        Arc::ptr_eq(&self.buf, &other.buf)
    }

    /// Contiguous element copy within the slab — the KV-arena slot
    /// remap primitive (a single memcpy per layer tensor). Ranges must
    /// be disjoint and in bounds.
    pub fn copy_within(&self, src: usize, dst: usize, len: usize) {
        assert!(
            src + len <= self.buf.len && dst + len <= self.buf.len,
            "SharedSlab::copy_within out of bounds"
        );
        assert!(
            src + len <= dst || dst + len <= src,
            "SharedSlab::copy_within requires disjoint ranges"
        );
        // SAFETY: in-bounds (asserted) and disjoint (asserted); callers
        // only move slots while the kernel is quiesced (module doc).
        unsafe {
            std::ptr::copy_nonoverlapping(self.buf.ptr.add(src), self.buf.ptr.add(dst), len);
        }
    }

    /// Copy a range out (tests/diagnostics; not a hot-path API).
    pub fn read(&self, off: usize, len: usize) -> Vec<f32> {
        assert!(off + len <= self.buf.len, "SharedSlab::read out of bounds");
        // SAFETY: in bounds; read-only snapshot under the contract.
        unsafe { std::slice::from_raw_parts(self.buf.ptr.add(off), len).to_vec() }
    }

    /// Copy a range in (host staging while the kernel is quiesced).
    pub fn write(&self, off: usize, data: &[f32]) {
        assert!(off + data.len() <= self.buf.len, "SharedSlab::write out of bounds");
        // SAFETY: in bounds; staging writes run only while no kernel
        // task is in flight (module doc).
        unsafe { std::ptr::copy(data.as_ptr(), self.buf.ptr.add(off), data.len()) }
    }

    /// Borrow a contiguous element span without copying — the paged-KV
    /// read primitive: the binder resolves a block table entry to a
    /// `(offset, len)` span per physical block and hands attention a
    /// strided run of these views instead of one slot-contiguous slice,
    /// so block-table indirection is pointer arithmetic, not a per-step
    /// allocation (the zero-copy counters never see it).
    pub fn view_span(&self, off: usize, len: usize) -> &[f32] {
        assert!(off + len <= self.buf.len, "SharedSlab::view_span out of bounds");
        // SAFETY: in bounds (asserted). Soundness of the borrow is the
        // aliasing contract's: a span is only viewed while the event
        // graph guarantees no in-flight task writes an overlapping
        // region — same writer-before-reader argument as
        // `TensorStore::view_region`, plus the block-aliasing rule
        // (shared blocks are read-only until COW re-points the writer
        // at a private copy before the kernel runs).
        unsafe { std::slice::from_raw_parts(self.buf.ptr.add(off), len) }
    }
}

/// Per-tensor placement: which slab, at what element offset.
struct TensorEntry {
    slab: usize,
    offset: usize,
    shape: Vec<usize>,
    numel: usize,
}

/// Read-side materialization counters (atomics; see module doc).
#[derive(Default)]
struct Counters {
    allocs: AtomicU64,
    bytes_copied: AtomicU64,
}

/// Plain-data snapshot of the store counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StoreCounters {
    /// Buffers allocated to materialize reads (`get` / `read_tile`).
    pub allocs: u64,
    /// Bytes memcpy'd by owned reads and `copy_tile_from` migrations.
    pub bytes_copied: u64,
}

#[cfg(debug_assertions)]
struct InflightAccess {
    id: u64,
    t: TensorId,
    region: Region,
    write: bool,
}

/// Debug-build token for an in-flight tile access; deregisters on drop.
/// Zero-sized in release builds.
pub struct AccessGuard<'a> {
    #[cfg(debug_assertions)]
    store: &'a TensorStore,
    #[cfg(debug_assertions)]
    id: u64,
    #[cfg(not(debug_assertions))]
    _p: std::marker::PhantomData<&'a TensorStore>,
}

impl Drop for AccessGuard<'_> {
    fn drop(&mut self) {
        #[cfg(debug_assertions)]
        {
            let mut g = self.store.inflight.lock().unwrap_or_else(|p| p.into_inner());
            if let Some(pos) = g.iter().position(|e| e.id == self.id) {
                g.swap_remove(pos);
            }
        }
    }
}

/// Flat-arena tensor storage, indexed by graph tensor id.
pub struct TensorStore {
    /// `slabs[0]` is the store's own packed slab; further entries are
    /// shared slabs aliased in at construction (the KV arena).
    slabs: Vec<Arc<ArenaBuf>>,
    entries: Vec<TensorEntry>,
    counters: Counters,
    #[cfg(debug_assertions)]
    inflight: Mutex<Vec<InflightAccess>>,
    #[cfg(debug_assertions)]
    next_access: AtomicU64,
}

impl TensorStore {
    /// Zero-initialized arena holding every tensor of `g`.
    pub fn new(g: &CompGraph) -> Self {
        Self::new_with_aliases(g, Vec::new())
    }

    /// Arena where the listed tensors alias external [`SharedSlab`]s at
    /// the given element offsets instead of living in the store's own
    /// slab. The aliased spans must fit their slabs; distinct aliased
    /// tensors must not overlap (the engine maps each KV tensor to its
    /// own arena segment).
    pub fn new_with_aliases(g: &CompGraph, aliases: Vec<(TensorId, SharedSlab, usize)>) -> Self {
        let alias_map: HashMap<TensorId, (SharedSlab, usize)> =
            aliases.into_iter().map(|(t, s, o)| (t, (s, o))).collect();
        let mut shared: Vec<SharedSlab> = Vec::new();
        let mut entries = Vec::with_capacity(g.tensors.len());
        let mut own_len = 0usize;
        for t in &g.tensors {
            let numel = t.numel();
            if let Some((slab, offset)) = alias_map.get(&t.id) {
                assert!(
                    offset + numel <= slab.len(),
                    "aliased tensor {} ({} elems at offset {offset}) exceeds shared slab ({})",
                    t.id,
                    numel,
                    slab.len()
                );
                let idx = match shared.iter().position(|s| s.same_slab(slab)) {
                    Some(i) => i,
                    None => {
                        shared.push(slab.clone());
                        shared.len() - 1
                    }
                };
                entries.push(TensorEntry {
                    slab: idx + 1,
                    offset: *offset,
                    shape: t.shape.clone(),
                    numel,
                });
            } else {
                entries.push(TensorEntry {
                    slab: 0,
                    offset: own_len,
                    shape: t.shape.clone(),
                    numel,
                });
                own_len += numel;
            }
        }
        let mut slabs = Vec::with_capacity(1 + shared.len());
        slabs.push(Arc::new(ArenaBuf::new(own_len)));
        slabs.extend(shared.into_iter().map(|s| s.buf));
        TensorStore {
            slabs,
            entries,
            counters: Counters::default(),
            #[cfg(debug_assertions)]
            inflight: Mutex::new(Vec::new()),
            #[cfg(debug_assertions)]
            next_access: AtomicU64::new(0),
        }
    }

    pub fn shape(&self, t: TensorId) -> &[usize] {
        &self.entries[t].shape
    }

    /// Elements in the store's **own** packed slab — excludes tensors
    /// aliased into shared slabs. The serving engine asserts with this
    /// that per-session stores no longer duplicate weights or KV: a
    /// session's own slab holds only its activations.
    pub fn owned_len(&self) -> usize {
        self.slabs[0].len
    }

    pub fn numel(&self, t: TensorId) -> usize {
        self.entries[t].numel
    }

    /// Snapshot of the read-side materialization counters.
    pub fn counters(&self) -> StoreCounters {
        StoreCounters {
            allocs: self.counters.allocs.load(Ordering::Relaxed),
            bytes_copied: self.counters.bytes_copied.load(Ordering::Relaxed),
        }
    }

    pub fn reset_counters(&self) {
        self.counters.allocs.store(0, Ordering::Relaxed);
        self.counters.bytes_copied.store(0, Ordering::Relaxed);
    }

    fn base_ptr(&self, t: TensorId) -> *mut f32 {
        let e = &self.entries[t];
        // SAFETY: `offset + numel <= slab.len` by construction; the
        // pointer stays within the slab allocation.
        unsafe { self.slabs[e.slab].ptr.add(e.offset) }
    }

    /// Register an access in the debug in-flight table, panicking on a
    /// write/any or any/write overlap. No-op in release builds.
    #[allow(unused_variables)]
    fn track(&self, t: TensorId, region: &Region, write: bool) -> AccessGuard<'_> {
        #[cfg(debug_assertions)]
        {
            let mut g = self.inflight.lock().unwrap_or_else(|p| p.into_inner());
            for e in g.iter() {
                if e.t == t && (write || e.write) && e.region.overlaps(region) {
                    panic!(
                        "arena aliasing violation on tensor {t}: {} {region} overlaps in-flight {} {}",
                        if write { "write" } else { "read" },
                        if e.write { "write" } else { "read" },
                        e.region,
                    );
                }
            }
            let id = self.next_access.fetch_add(1, Ordering::Relaxed);
            g.push(InflightAccess { id, t, region: region.clone(), write });
            drop(g);
            return AccessGuard { store: self, id };
        }
        #[cfg(not(debug_assertions))]
        {
            AccessGuard { _p: std::marker::PhantomData }
        }
    }

    /// Borrow the whole tensor, zero-copy. Sound under the module-doc
    /// contract: the caller's task must be ordered after every writer of
    /// this tensor by the event graph (untracked even in debug builds).
    pub fn view(&self, t: TensorId) -> &[f32] {
        let e = &self.entries[t];
        // SAFETY: in-bounds span; no in-flight writer overlaps per the
        // aliasing contract.
        unsafe { std::slice::from_raw_parts(self.base_ptr(t), e.numel) }
    }

    /// Borrow an axis-aligned tile as a strided view (no copy). The view
    /// is registered as an in-flight read in debug builds for its whole
    /// lifetime.
    pub fn tile<'s, 'r>(&'s self, t: TensorId, r: &'r Region) -> TileView<'s, 'r> {
        let e = &self.entries[t];
        check_region(&e.shape, r, t);
        let guard = self.track(t, r, false);
        TileView { store: self, t, region: r, run: run_len(r), _guard: guard }
    }

    /// Borrow a tile that is contiguous in the row-major layout (leading
    /// unit dims, one free dim, full trailing dims) as a plain slice.
    /// Panics if the region is strided — the binder uses this for the
    /// per-row attention/KV slices that are contiguous by construction.
    /// Debug builds register a *call-scoped* read (an in-flight
    /// overlapping write at creation time panics); the returned slice
    /// itself is untracked, like [`TensorStore::view`].
    pub fn view_region(&self, t: TensorId, r: &Region) -> &[f32] {
        let e = &self.entries[t];
        check_region(&e.shape, r, t);
        let _g = self.track(t, r, false);
        let (start, len) = contiguous_span(&e.shape, r)
            .unwrap_or_else(|| panic!("region {r} of tensor {t} is not contiguous"));
        // SAFETY: `start + len` lies within the tensor span (region is
        // bounds-checked); aliasing per the module contract.
        unsafe { std::slice::from_raw_parts(self.base_ptr(t).add(start), len) }
    }

    /// Borrow a contiguous tile **mutably** — the write counterpart of
    /// [`TensorStore::view_region`], for host staging of an exclusively
    /// owned region. Panics if the region is strided.
    ///
    /// **Contract (sharper than the read-side views):** the caller must
    /// own this region for the whole life of the returned slice — two
    /// live `view_region_mut` slices over overlapping regions, or one
    /// overlapping any concurrent access, is undefined behavior exactly
    /// like any `&mut` aliasing, and the event graph is what rules it
    /// out for task code. Debug builds register only a *call-scoped*
    /// write (an in-flight overlapping access at creation time panics);
    /// the returned slice itself is untracked. Prefer
    /// [`TensorStore::tile_mut`], whose registration (and borrow) spans
    /// the whole use — the binder and pool destinations use that form;
    /// this one exists for short staging writes and tests.
    // clippy::mut_from_ref: the arena is shared and lock-free by
    // design; disjoint mutable regions are handed out from `&self`
    // under the module aliasing contract (there is no `&mut self` to
    // thread through concurrently executing tasks).
    #[allow(clippy::mut_from_ref)]
    pub fn view_region_mut(&self, t: TensorId, r: &Region) -> &mut [f32] {
        let e = &self.entries[t];
        check_region(&e.shape, r, t);
        let _g = self.track(t, r, true);
        let (start, len) = contiguous_span(&e.shape, r)
            .unwrap_or_else(|| panic!("region {r} of tensor {t} is not contiguous"));
        // SAFETY: `start + len` lies within the tensor span (region is
        // bounds-checked); the caller owns this write region under the
        // module aliasing contract, so no other live reference overlaps.
        unsafe { std::slice::from_raw_parts_mut(self.base_ptr(t).add(start), len) }
    }

    /// Borrow an axis-aligned tile **mutably** as a strided view. The
    /// view is registered as an in-flight write in debug builds for its
    /// whole lifetime — the form task bodies hold across an
    /// `ExecPool::execute_into` call so the tracker sees the executor
    /// thread's writes as this task's.
    pub fn tile_mut<'s, 'r>(&'s self, t: TensorId, r: &'r Region) -> TileViewMut<'s, 'r> {
        let e = &self.entries[t];
        check_region(&e.shape, r, t);
        let guard = self.track(t, r, true);
        TileViewMut { store: self, t, region: r, run: run_len(r), _guard: guard }
    }

    /// Overwrite the whole tensor from a slice (host staging: weights,
    /// token ids). Not counted as a copy — results/staging must land in
    /// the arena.
    pub fn set(&self, t: TensorId, data: &[f32]) {
        let e = &self.entries[t];
        assert_eq!(e.numel, data.len(), "tensor {t} size mismatch");
        // the debug tracker needs a Region, which is heap-backed —
        // build it only where the tracker exists (release `track` is a
        // no-op; staging writes must not pay a per-call allocation).
        #[cfg(debug_assertions)]
        let _g = self.track(t, &Region::full(&e.shape), true);
        // SAFETY: exact-span write; `copy` (memmove) tolerates a caller
        // passing a view of this very tensor.
        unsafe { std::ptr::copy(data.as_ptr(), self.base_ptr(t), data.len()) }
    }

    /// Copy of the whole buffer (validation/harvest paths — counted).
    pub fn get(&self, t: TensorId) -> Vec<f32> {
        self.counters.allocs.fetch_add(1, Ordering::Relaxed);
        self.counters
            .bytes_copied
            .fetch_add((self.entries[t].numel * 4) as u64, Ordering::Relaxed);
        self.view(t).to_vec()
    }

    /// Copy out an axis-aligned tile into a fresh `Vec` (counted). The
    /// hot path uses [`TensorStore::tile`] / [`TensorStore::view_region`]
    /// instead.
    pub fn read_tile(&self, t: TensorId, r: &Region) -> Vec<f32> {
        self.counters.allocs.fetch_add(1, Ordering::Relaxed);
        self.counters.bytes_copied.fetch_add((r.numel() * 4) as u64, Ordering::Relaxed);
        self.tile(t, r).to_vec()
    }

    /// Copy a tile in (row-major within the tile).
    pub fn write_tile(&self, t: TensorId, r: &Region, data: &[f32]) {
        let e = &self.entries[t];
        check_region(&e.shape, r, t);
        assert_eq!(r.numel(), data.len(), "tile data size mismatch for tensor {t}");
        if r.is_empty() {
            return;
        }
        let _g = self.track(t, r, true);
        let run = run_len(r);
        let base = self.base_ptr(t);
        let mut off = 0usize;
        for_each_run(&e.shape, r, &mut |b| {
            // SAFETY: `b + run` is inside the tensor span (region is
            // bounds-checked); `copy` tolerates `data` borrowing another
            // region of the same slab (KvAppend copies qkv → cache).
            unsafe { std::ptr::copy(data.as_ptr().add(off), base.add(b), run) };
            off += run;
        });
    }

    /// Copy a tile from another tensor into this one (counted as
    /// migration bytes). Panics if the regions' per-dimension extents
    /// differ, or if source and destination are the same tensor with
    /// *overlapping* regions (slot moves are always disjoint — kept as
    /// a contract even though the buffered implementation would
    /// tolerate overlap). This is a **cold host-staging path** built on
    /// the safe tile primitives — materialize, then write — so it adds
    /// no unsafe surface and is trivially correct for tensors aliasing
    /// the same [`SharedSlab`]; the serving engine's hot KV slot remaps
    /// go through [`SharedSlab::copy_within`] instead.
    pub fn copy_tile_from(
        &self,
        t: TensorId,
        r: &Region,
        src: &TensorStore,
        src_t: TensorId,
        src_r: &Region,
    ) {
        assert_eq!(r.rank(), src_r.rank(), "tile rank mismatch");
        for (d, (a, b)) in r.dims.iter().zip(src_r.dims.iter()).enumerate() {
            assert_eq!(a.1 - a.0, b.1 - b.0, "extent mismatch in dim {d}");
        }
        if r.is_empty() {
            return;
        }
        if std::ptr::eq(self, src) && t == src_t {
            assert!(
                r.dims
                    .iter()
                    .zip(src_r.dims.iter())
                    .any(|(&(d0, d1), &(s0, s1))| d1 <= s0 || s1 <= d0),
                "same-tensor copy_tile_from requires disjoint regions"
            );
        }
        self.counters.bytes_copied.fetch_add((r.numel() * 4) as u64, Ordering::Relaxed);
        let data = src.tile(src_t, src_r).to_vec();
        self.write_tile(t, r, &data);
    }
}

/// Strided, zero-copy view over an axis-aligned tile.
pub struct TileView<'s, 'r> {
    store: &'s TensorStore,
    t: TensorId,
    region: &'r Region,
    run: usize,
    _guard: AccessGuard<'s>,
}

impl<'s> TileView<'s, '_> {
    pub fn numel(&self) -> usize {
        self.region.numel()
    }

    /// Length of the contiguous innermost run.
    pub fn run_len(&self) -> usize {
        self.run
    }

    /// Visit each contiguous innermost run as a borrowed slice, in
    /// region row-major order. No heap allocation.
    pub fn for_each_run(&self, f: &mut impl FnMut(&[f32])) {
        if self.region.is_empty() {
            return;
        }
        let shape = &self.store.entries[self.t].shape;
        let base = self.store.base_ptr(self.t);
        let run = self.run;
        for_each_run(shape, self.region, &mut |b| {
            // SAFETY: run bounds-checked at construction; read-only
            // under the aliasing contract.
            f(unsafe { std::slice::from_raw_parts(base.add(b), run) });
        });
    }

    /// Gather the tile into a reusable buffer (cleared first). After
    /// warm-up the buffer's capacity suffices and this performs zero
    /// allocations — the per-worker scratch path in the binder.
    pub fn gather_into(&self, out: &mut Vec<f32>) {
        out.clear();
        out.reserve(self.numel());
        self.for_each_run(&mut |r| out.extend_from_slice(r));
    }

    /// Materialize into a fresh `Vec` (cold paths).
    pub fn to_vec(&self) -> Vec<f32> {
        let mut v = Vec::with_capacity(self.numel());
        self.for_each_run(&mut |r| v.extend_from_slice(r));
        v
    }

    /// The tile as one borrowed slice, if it is contiguous in the
    /// tensor's row-major layout. The slice borrows the *store* (not
    /// this view), so it may outlive the view's debug read-tracking.
    pub fn as_slice(&self) -> Option<&'s [f32]> {
        let shape = &self.store.entries[self.t].shape;
        contiguous_span(shape, self.region).map(|(start, len)| {
            // SAFETY: span is inside the tensor (bounds-checked at
            // construction); aliasing per the module contract.
            unsafe { std::slice::from_raw_parts(self.store.base_ptr(self.t).add(start), len) }
        })
    }
}

/// Strided, mutable view over an axis-aligned tile — the destination
/// side of the zero-copy hot path. Registered as an in-flight write in
/// debug builds for its whole lifetime.
pub struct TileViewMut<'s, 'r> {
    store: &'s TensorStore,
    t: TensorId,
    region: &'r Region,
    run: usize,
    _guard: AccessGuard<'s>,
}

impl TileViewMut<'_, '_> {
    pub fn numel(&self) -> usize {
        self.region.numel()
    }

    /// Length of the contiguous innermost run.
    pub fn run_len(&self) -> usize {
        self.run
    }

    /// Visit each contiguous innermost run as a mutable slice, in
    /// region row-major order. No heap allocation.
    pub fn for_each_run_mut(&mut self, f: &mut impl FnMut(&mut [f32])) {
        if self.region.is_empty() {
            return;
        }
        let shape = &self.store.entries[self.t].shape;
        let base = self.store.base_ptr(self.t);
        let run = self.run;
        for_each_run(shape, self.region, &mut |b| {
            // SAFETY: run bounds-checked at construction; this view is
            // the region's only writer under the aliasing contract, and
            // the runs it visits are disjoint.
            f(unsafe { std::slice::from_raw_parts_mut(base.add(b), run) });
        });
    }

    /// Copy `data` (tile row-major) into the tile — `write_tile`
    /// through an already-registered mutable view (the binder's
    /// fallback when a pool output cannot land directly).
    pub fn scatter_from(&mut self, data: &[f32]) {
        assert_eq!(data.len(), self.numel(), "tile data size mismatch for tensor {}", self.t);
        let run = self.run;
        let mut off = 0usize;
        self.for_each_run_mut(&mut |dst| {
            dst.copy_from_slice(&data[off..off + run]);
            off += run;
        });
    }

    /// The tile as one mutable slice, if it is contiguous in the
    /// tensor's row-major layout.
    pub fn as_slice_mut(&mut self) -> Option<&mut [f32]> {
        let shape = &self.store.entries[self.t].shape;
        contiguous_span(shape, self.region).map(|(start, len)| {
            // SAFETY: span is inside the tensor (bounds-checked at
            // construction); exclusive under the aliasing contract.
            unsafe { std::slice::from_raw_parts_mut(self.store.base_ptr(self.t).add(start), len) }
        })
    }

    /// Pool output destination covering this tile, if the tile maps to
    /// **regularly strided** runs: contiguous, or exactly one non-unit
    /// dim before the innermost run (runs then advance by that dim's
    /// row-major stride). Every output tile the real decode graph
    /// produces is regular — whole tensors and per-row attention
    /// outputs are contiguous, matmul column tiles are one run per
    /// output row — so the persistent-kernel task bodies pass these to
    /// `ExecPool::execute_into` and results land in the arena with no
    /// intermediate buffer. Returns `None` for an irregular tile
    /// (caller scatters via [`TileViewMut::scatter_from`] instead).
    ///
    /// The returned view borrows this `TileViewMut` mutably, so the
    /// debug write registration (and the exclusive borrow) spans the
    /// whole pool call it is used in.
    pub fn out_view(&mut self) -> Option<OutView<'_>> {
        let e = &self.store.entries[self.t];
        let rank = e.shape.len();
        if let Some((start, len)) = contiguous_span(&e.shape, self.region) {
            // SAFETY: in-bounds span (bounds-checked at construction);
            // this view holds the region's exclusive write borrow.
            return Some(unsafe {
                OutView::from_raw_strided(self.store.base_ptr(self.t).add(start), 1, len, len)
            });
        }
        // not contiguous ⇒ at least one non-unit outer dim; regular
        // exactly when there is only one.
        let mut strides = [1usize; MAX_RANK];
        for d in (0..rank.saturating_sub(1)).rev() {
            strides[d] = strides[d + 1] * e.shape[d + 1];
        }
        let mut free: Option<usize> = None;
        for d in 0..rank - 1 {
            if self.region.extent(d) > 1 {
                if free.is_some() {
                    return None;
                }
                free = Some(d);
            }
        }
        let d = free?;
        let start: usize = (0..rank).map(|q| self.region.dims[q].0 * strides[q]).sum();
        // SAFETY: every run lies inside the tensor span (the region is
        // bounds-checked and runs follow its row-major walk); exclusive
        // write borrow as above. run ≤ stride keeps the runs disjoint.
        Some(unsafe {
            OutView::from_raw_strided(
                self.store.base_ptr(self.t).add(start),
                self.region.extent(d),
                self.run,
                strides[d],
            )
        })
    }
}

/// Panic unless `r` is a well-formed region inside `shape`.
fn check_region(shape: &[usize], r: &Region, t: TensorId) {
    assert_eq!(r.rank(), shape.len(), "tile rank mismatch for tensor {t}");
    assert!(r.rank() >= 1 && r.rank() <= MAX_RANK, "unsupported rank {} for tensor {t}", r.rank());
    for (d, &(s, e)) in r.dims.iter().enumerate() {
        assert!(s <= e && e <= shape[d], "region {r} out of bounds in dim {d} for tensor {t}");
    }
}

/// Length of the contiguous innermost run of `region`.
fn run_len(region: &Region) -> usize {
    let (s, e) = region.dims[region.rank() - 1];
    e - s
}

/// `Some((start_offset, len))` if `region` maps to one contiguous
/// row-major span of its tensor: any leading unit-extent dims, then at
/// most one free dim, then full trailing dims.
fn contiguous_span(shape: &[usize], region: &Region) -> Option<(usize, usize)> {
    let rank = shape.len();
    let mut d = 0;
    while d < rank && region.extent(d) == 1 {
        d += 1;
    }
    for q in (d + 1)..rank {
        if region.dims[q] != (0, shape[q]) {
            return None;
        }
    }
    let mut start = 0usize;
    let mut stride = 1usize;
    for q in (0..rank).rev() {
        start += region.dims[q].0 * stride;
        stride *= shape[q];
    }
    Some((start, region.numel()))
}

/// Call `f(base)` with the row-major start offset of each contiguous
/// innermost run of `region` within a buffer of `shape`, in region
/// row-major order. Stack state only (rank ≤ [`MAX_RANK`]) — the tile
/// hot path allocates nothing.
fn for_each_run(shape: &[usize], region: &Region, f: &mut impl FnMut(usize)) {
    let rank = shape.len();
    debug_assert!(rank >= 1 && rank <= MAX_RANK);
    if region.is_empty() {
        return;
    }
    let mut strides = [1usize; MAX_RANK];
    for d in (0..rank.saturating_sub(1)).rev() {
        strides[d] = strides[d + 1] * shape[d + 1];
    }
    let (last_s, _) = region.dims[rank - 1];
    let mut idx = [0usize; MAX_RANK];
    for d in 0..rank - 1 {
        idx[d] = region.dims[d].0;
    }
    loop {
        let base: usize =
            (0..rank - 1).map(|d| idx[d] * strides[d]).sum::<usize>() + last_s;
        f(base);
        // advance multi-index over the outer dims.
        let mut d = rank.wrapping_sub(2);
        loop {
            if d == usize::MAX {
                return;
            }
            idx[d] += 1;
            if idx[d] < region.dims[d].1 {
                break;
            }
            idx[d] = region.dims[d].0;
            d = d.wrapping_sub(1);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::{DType, OpKind};

    fn store_2d() -> (TensorStore, TensorId) {
        let mut g = CompGraph::new();
        let t = g.input("x", vec![4, 6], DType::F32);
        let w = g.param("w", vec![6, 2], DType::F32);
        g.op("y", OpKind::MatMul, &[t, w], vec![4, 2], DType::F32);
        (TensorStore::new(&g), t)
    }

    #[test]
    fn whole_tensor_roundtrip() {
        let (s, t) = store_2d();
        let data: Vec<f32> = (0..24).map(|i| i as f32).collect();
        s.set(t, &data);
        assert_eq!(s.get(t), data);
        assert_eq!(s.view(t), &data[..]);
    }

    #[test]
    fn tile_read_matches_manual_slice() {
        let (s, t) = store_2d();
        s.set(t, &(0..24).map(|i| i as f32).collect::<Vec<_>>());
        // rows 1..3, cols 2..5 of a 4x6 row-major buffer
        let tile = s.read_tile(t, &Region::new(vec![(1, 3), (2, 5)]));
        assert_eq!(tile, vec![8.0, 9.0, 10.0, 14.0, 15.0, 16.0]);
        // borrowed view gathers the same data without counting an alloc.
        s.reset_counters();
        let r = Region::new(vec![(1, 3), (2, 5)]);
        let mut buf = Vec::new();
        s.tile(t, &r).gather_into(&mut buf);
        assert_eq!(buf, tile);
        assert_eq!(s.counters(), StoreCounters::default());
    }

    #[test]
    fn tile_write_then_read() {
        let (s, t) = store_2d();
        let r = Region::new(vec![(2, 4), (0, 3)]);
        s.write_tile(t, &r, &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(s.read_tile(t, &r), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        // untouched region stays zero
        assert_eq!(s.read_tile(t, &Region::new(vec![(0, 2), (0, 6)])), vec![0.0; 12]);
    }

    #[test]
    fn rank3_tiles() {
        let mut g = CompGraph::new();
        let t = g.input("c", vec![2, 3, 4], DType::F32);
        let s = TensorStore::new(&g);
        s.set(t, &(0..24).map(|i| i as f32).collect::<Vec<_>>());
        // [1:2, 0:3, 1:3]
        let tile = s.read_tile(t, &Region::new(vec![(1, 2), (0, 3), (1, 3)]));
        assert_eq!(tile, vec![13.0, 14.0, 17.0, 18.0, 21.0, 22.0]);
        // write a row of the cache (KvAppend pattern)
        s.write_tile(t, &Region::new(vec![(0, 1), (2, 3), (0, 4)]), &[9.0; 4]);
        let back = s.read_tile(t, &Region::new(vec![(0, 1), (2, 3), (0, 4)]));
        assert_eq!(back, vec![9.0; 4]);
    }

    #[test]
    fn contiguous_view_region() {
        let mut g = CompGraph::new();
        let t = g.input("kc", vec![4, 8, 2], DType::F32);
        let s = TensorStore::new(&g);
        s.set(t, &(0..64).map(|i| i as f32).collect::<Vec<_>>());
        // one full slot: [2:3, 0:8, 0:2] is contiguous.
        let v = s.view_region(t, &Region::new(vec![(2, 3), (0, 8), (0, 2)]));
        assert_eq!(v, (32..48).map(|i| i as f32).collect::<Vec<_>>());
        // one cache row: [1:2, 3:4, 0:2] is contiguous.
        let v = s.view_region(t, &Region::new(vec![(1, 2), (3, 4), (0, 2)]));
        assert_eq!(v, vec![22.0, 23.0]);
        // leading free dim over full trailing dims is contiguous too.
        let v = s.view_region(t, &Region::new(vec![(1, 3), (0, 8), (0, 2)]));
        assert_eq!(v.len(), 32);
        // a strided tile is not.
        let r = Region::new(vec![(0, 2), (1, 3), (0, 2)]);
        assert!(s.tile(t, &r).as_slice().is_none());
    }

    #[test]
    #[should_panic(expected = "not contiguous")]
    fn strided_view_region_panics() {
        let (s, t) = store_2d();
        s.view_region(t, &Region::new(vec![(0, 2), (1, 3)]));
    }

    #[test]
    fn copy_tile_from_between_stores() {
        // two stores with different batch dims, as in KV migration
        // between batch-size-specialized sessions.
        let mut g_src = CompGraph::new();
        let ts = g_src.input("kc", vec![2, 4, 3], DType::F32);
        let src = TensorStore::new(&g_src);
        src.set(ts, &(0..24).map(|i| i as f32).collect::<Vec<_>>());

        let mut g_dst = CompGraph::new();
        let td = g_dst.input("kc", vec![4, 4, 3], DType::F32);
        let dst = TensorStore::new(&g_dst);

        // migrate src slot 1, rows 0..2 → dst slot 3, rows 0..2.
        dst.copy_tile_from(
            td,
            &Region::new(vec![(3, 4), (0, 2), (0, 3)]),
            &src,
            ts,
            &Region::new(vec![(1, 2), (0, 2), (0, 3)]),
        );
        let got = dst.read_tile(td, &Region::new(vec![(3, 4), (0, 2), (0, 3)]));
        let want = src.read_tile(ts, &Region::new(vec![(1, 2), (0, 2), (0, 3)]));
        assert_eq!(got, want);
        assert_eq!(got, vec![12.0, 13.0, 14.0, 15.0, 16.0, 17.0]);
        // rest of dst untouched.
        assert_eq!(dst.read_tile(td, &Region::new(vec![(0, 3), (0, 4), (0, 3)])), vec![0.0; 36]);
    }

    #[test]
    fn copy_tile_from_different_tensors_same_store() {
        let mut g = CompGraph::new();
        let a = g.input("a", vec![2, 6], DType::F32);
        let b = g.input("b", vec![2, 6], DType::F32);
        let s = TensorStore::new(&g);
        s.set(a, &(0..12).map(|i| i as f32).collect::<Vec<_>>());
        s.copy_tile_from(b, &Region::new(vec![(0, 2), (0, 6)]), &s, a, &Region::new(vec![(0, 2), (0, 6)]));
        assert_eq!(s.get(b), s.get(a));
    }

    #[test]
    fn copy_tile_from_same_tensor_disjoint_slots() {
        // intra-tensor slot compaction: move slot 2's rows into slot 0.
        let mut g = CompGraph::new();
        let t = g.input("kc", vec![3, 4, 2], DType::F32);
        let s = TensorStore::new(&g);
        s.set(t, &(0..24).map(|i| i as f32).collect::<Vec<_>>());
        let src = Region::new(vec![(2, 3), (0, 3), (0, 2)]);
        let want = s.read_tile(t, &src);
        s.copy_tile_from(t, &Region::new(vec![(0, 1), (0, 3), (0, 2)]), &s, t, &src);
        assert_eq!(s.read_tile(t, &Region::new(vec![(0, 1), (0, 3), (0, 2)])), want);
        // source slot is left as-is (dead data for the engine).
        assert_eq!(s.read_tile(t, &src), want);
    }

    #[test]
    #[should_panic(expected = "disjoint regions")]
    fn copy_tile_from_same_tensor_overlap_panics() {
        let (s, t) = store_2d();
        s.copy_tile_from(
            t,
            &Region::new(vec![(0, 2), (0, 6)]),
            &s,
            t,
            &Region::new(vec![(1, 3), (0, 6)]),
        );
    }

    #[test]
    fn concurrent_disjoint_tile_writes() {
        let (s, t) = store_2d();
        std::thread::scope(|sc| {
            for row in 0..4 {
                let s = &s;
                sc.spawn(move || {
                    s.write_tile(t, &Region::new(vec![(row, row + 1), (0, 6)]), &[row as f32; 6]);
                });
            }
        });
        for row in 0..4 {
            let tile = s.read_tile(t, &Region::new(vec![(row, row + 1), (0, 6)]));
            assert_eq!(tile, vec![row as f32; 6]);
        }
    }

    #[test]
    fn counters_track_owned_reads_only() {
        let (s, t) = store_2d();
        s.set(t, &[1.0; 24]);
        assert_eq!(s.counters(), StoreCounters::default(), "set must not count");
        let _ = s.view(t);
        let r = Region::new(vec![(0, 2), (0, 6)]);
        let v = s.tile(t, &r);
        let mut acc = 0.0;
        v.for_each_run(&mut |run| acc += run.iter().sum::<f32>());
        drop(v);
        assert_eq!(acc, 12.0);
        assert_eq!(s.counters(), StoreCounters::default(), "views must not count");
        let _ = s.get(t);
        let _ = s.read_tile(t, &r);
        let c = s.counters();
        assert_eq!(c.allocs, 2);
        assert_eq!(c.bytes_copied, (24 + 12) * 4);
        s.reset_counters();
        assert_eq!(s.counters(), StoreCounters::default());
    }

    #[test]
    fn shared_slab_aliases_across_stores() {
        // two "sessions" with different batch dims aliasing one KV slab:
        // writes through one store are visible through the other, and
        // the small store's tensor is a prefix of the big one's.
        let slab = SharedSlab::new(4 * 4 * 2); // 4 slots × 4 rows × kv_dim 2
        let mut g_small = CompGraph::new();
        let ts = g_small.input("kc", vec![2, 4, 2], DType::F32);
        let small = TensorStore::new_with_aliases(&g_small, vec![(ts, slab.clone(), 0)]);
        let mut g_big = CompGraph::new();
        let tb = g_big.input("kc", vec![4, 4, 2], DType::F32);
        let big = TensorStore::new_with_aliases(&g_big, vec![(tb, slab.clone(), 0)]);

        small.write_tile(ts, &Region::new(vec![(1, 2), (0, 1), (0, 2)]), &[7.0, 8.0]);
        assert_eq!(
            big.read_tile(tb, &Region::new(vec![(1, 2), (0, 1), (0, 2)])),
            vec![7.0, 8.0]
        );
        // slot remap = one contiguous memmove on the slab: slot 1 → 3.
        slab.copy_within(8, 24, 8);
        assert_eq!(
            big.read_tile(tb, &Region::new(vec![(3, 4), (0, 1), (0, 2)])),
            vec![7.0, 8.0]
        );
        // the small store never sees slots beyond its batch dim.
        assert_eq!(small.numel(ts), 16);
        assert_eq!(big.numel(tb), 32);
    }

    #[test]
    #[should_panic(expected = "exceeds shared slab")]
    fn oversized_alias_rejected() {
        let slab = SharedSlab::new(4);
        let mut g = CompGraph::new();
        let t = g.input("kc", vec![2, 4], DType::F32);
        let _ = TensorStore::new_with_aliases(&g, vec![(t, slab, 0)]);
    }

    #[test]
    #[should_panic(expected = "out of bounds")]
    fn out_of_bounds_region_rejected() {
        let (s, t) = store_2d();
        s.write_tile(t, &Region::new(vec![(0, 5), (0, 6)]), &[0.0; 30]);
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "aliasing violation")]
    fn debug_mode_catches_overlapping_write_during_read() {
        let (s, t) = store_2d();
        let r = Region::new(vec![(0, 2), (0, 6)]);
        let v = s.tile(t, &r); // in-flight read
        s.write_tile(t, &Region::new(vec![(1, 3), (0, 6)]), &[0.0; 12]);
        drop(v);
    }

    #[test]
    fn view_region_mut_writes_land_in_the_arena() {
        let (s, t) = store_2d();
        s.set(t, &[0.0; 24]);
        // one full row of the 4x6 tensor is contiguous.
        let r = Region::new(vec![(2, 3), (0, 6)]);
        s.view_region_mut(t, &r).copy_from_slice(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(s.read_tile(t, &r), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        // neighbours untouched, and the write counted nothing.
        assert_eq!(s.read_tile(t, &Region::new(vec![(0, 2), (0, 6)])), vec![0.0; 12]);
        s.reset_counters();
        s.view_region_mut(t, &r)[0] = 9.0;
        assert_eq!(s.counters(), StoreCounters::default(), "mutable view moved the counters");
    }

    #[test]
    #[should_panic(expected = "not contiguous")]
    fn strided_view_region_mut_panics() {
        let (s, t) = store_2d();
        s.view_region_mut(t, &Region::new(vec![(0, 2), (1, 3)]));
    }

    #[test]
    fn tile_mut_scatter_matches_write_tile() {
        let (s, t) = store_2d();
        let (s2, t2) = store_2d();
        let r = Region::new(vec![(1, 3), (2, 5)]);
        let data: Vec<f32> = (0..6).map(|i| 100.0 + i as f32).collect();
        s.write_tile(t, &r, &data);
        s2.tile_mut(t2, &r).scatter_from(&data);
        assert_eq!(s.get(t), s2.get(t2));
    }

    #[test]
    fn out_view_layouts_match_the_binder_cases() {
        let mut g = CompGraph::new();
        let mm = g.input("mm_out", vec![4, 6], DType::F32); // matmul output [b, N]
        let q = g.input("attn_out", vec![4, 8], DType::F32); // attention output [b, q_dim]
        let c = g.input("kc", vec![2, 3, 4], DType::F32); // cache [slots, s_max, kv]
        let s = TensorStore::new(&g);
        // whole tensor: contiguous.
        assert!(s.tile_mut(mm, &Region::full(&[4, 6])).out_view().is_some());
        // matmul column tile: strided but regular (one run per row).
        assert!(s.tile_mut(mm, &Region::new(vec![(0, 4), (2, 4)])).out_view().is_some());
        // per-row attention output: contiguous.
        assert!(s.tile_mut(q, &Region::new(vec![(2, 3), (0, 8)])).out_view().is_some());
        // one cache row: contiguous.
        assert!(s
            .tile_mut(c, &Region::new(vec![(1, 2), (2, 3), (0, 4)]))
            .out_view()
            .is_some());
        // two non-unit outer dims with a partial tail: irregular.
        assert!(s
            .tile_mut(c, &Region::new(vec![(0, 2), (0, 2), (1, 3)]))
            .out_view()
            .is_none());
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "aliasing violation")]
    fn debug_mode_catches_write_write_overlap_on_mut_views() {
        let (s, t) = store_2d();
        let r = Region::new(vec![(0, 2), (0, 6)]);
        let v = s.tile_mut(t, &r); // in-flight write
        let _ = s.tile_mut(t, &Region::new(vec![(1, 3), (0, 6)]));
        drop(v);
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "aliasing violation")]
    fn debug_mode_catches_read_during_mut_view() {
        let (s, t) = store_2d();
        let r = Region::new(vec![(0, 2), (0, 6)]);
        let v = s.tile_mut(t, &r); // in-flight write
        let _ = s.read_tile(t, &Region::new(vec![(1, 3), (0, 6)]));
        drop(v);
    }
}
