//! The real-numerics end-to-end path: build the tiny-model graph with
//! artifact-aligned partition hints, synthesize deterministic weights,
//! run decode iterations on the persistent megakernel, and validate
//! against the fused reference artifact.

use crate::exec::binder::{OwningTileExecutor, TileExecutor};
use crate::exec::store::{SharedSlab, TensorStore};
use crate::megakernel::{MegaConfig, PersistentMegaKernel, RunReport};
use crate::models::{build_decode_graph, GraphOptions, ModelConfig};
use crate::ops::{CompGraph, DType, OpKind, TensorId};
use crate::runtime::backend::BackendKind;
use crate::runtime::manifest::ManifestError;
use crate::runtime::pool::{ExecPool, Value};
use crate::runtime::Manifest;
use crate::tgraph::{compile, CompileOptions, CompiledGraph, DecomposeConfig};
use crate::util::XorShift64;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Build the tiny-model decode graph whose tiles line up with the AOT
/// artifacts: matmuls tiled to `tile_n` columns, attention per request,
/// everything else whole-tensor. A manifest whose model metadata or
/// tile width disagrees with the compiled-in tiny model is a typed
/// [`ManifestError`] — a bad artifacts dir degrades into `EngineError`
/// at the serving layer instead of aborting the thread.
pub fn build_real_graph(manifest: &Manifest, batch: usize) -> Result<CompGraph, ManifestError> {
    let cfg = ModelConfig::tiny();
    let m = manifest.model;
    let got = (m.layers, m.d_model, m.heads, m.kv_heads, m.head_dim, m.ffn, m.vocab);
    let want = (cfg.layers, cfg.d_model, cfg.heads, cfg.kv_heads, cfg.head_dim, cfg.ffn, cfg.vocab);
    if got != want {
        return Err(ManifestError::ModelMismatch {
            manifest: format!("{got:?}"),
            builtin: format!("{want:?}"),
        });
    }
    let mut g = build_decode_graph(
        &cfg,
        &GraphOptions {
            batch,
            kv_len: manifest.s_max - 1,
            dtype: DType::F32,
            // explicit KvAppend: the artifact set has a separate native
            // append step (the fused variant is for the perf graphs).
            fused_kv_append: false,
            ..Default::default()
        },
    );
    let tile_n = manifest.tile_n;
    for op in g.ops.iter_mut() {
        let out_shape = op.output;
        let _ = out_shape;
        op.partition_hint = Some(match op.kind {
            OpKind::MatMul => vec![1, 0], // cols filled below
            OpKind::Attention { .. } => vec![batch, 1],
            _ => vec![1; 2],
        });
    }
    // second pass with shapes in hand (borrow rules: shapes are on g).
    let shapes: Vec<Vec<usize>> = g.ops.iter().map(|o| g.tensors[o.output].shape.clone()).collect();
    for (op, shape) in g.ops.iter_mut().zip(shapes) {
        match op.kind {
            OpKind::MatMul => {
                if shape[1] % tile_n != 0 {
                    return Err(ManifestError::NotTileable {
                        op: op.name.clone(),
                        n: shape[1],
                        tile_n,
                    });
                }
                op.partition_hint = Some(vec![1, shape[1] / tile_n]);
            }
            OpKind::Attention { .. } => {}
            _ => {
                op.partition_hint = Some(vec![1; shape.len()]);
            }
        }
    }
    Ok(g)
}

/// Compile the real graph for the megakernel.
pub fn compile_real(manifest: &Manifest, batch: usize) -> Result<CompiledGraph, ManifestError> {
    let g = build_real_graph(manifest, batch)?;
    Ok(compile(
        &g,
        &CompileOptions {
            decompose: DecomposeConfig { target_tasks: 8, min_tile_cols: 8 },
            ..Default::default()
        },
    ))
}

/// Deterministically synthesize one parameter's values: norm weights =
/// 1, projections ~ U(-0.05, 0.05). Seeded by tensor *name* so the same
/// weight gets identical values in every batch-size-specialized graph —
/// which is what lets every specialization alias one shared
/// [`WeightArena`] without re-initialization.
fn synth_param(name: &str, numel: usize, seed: u64) -> Vec<f32> {
    if name.contains("ln") || name.contains("norm") {
        vec![1.0; numel]
    } else {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for b in name.as_bytes() {
            h = (h ^ *b as u64).wrapping_mul(0x1000_0000_01b3);
        }
        let mut rng = XorShift64::new(seed ^ h);
        (0..numel).map(|_| rng.unit_f32() * 0.05).collect()
    }
}

/// Deterministically synthesize weights into the store (seeded per
/// tensor name; see [`synth_param`]). The single-session path — the
/// serving engine instead initializes one shared [`WeightArena`] that
/// all of its sessions alias.
pub fn init_weights(g: &CompGraph, store: &TensorStore, seed: u64) {
    for t in &g.tensors {
        if t.is_param {
            store.set(t.id, &synth_param(&t.name, t.numel(), seed));
        }
    }
}

/// One shared weight arena aliased by every batch-size specialization.
///
/// Parameter tensors are batch-independent — a `[d_model, q_dim]`
/// projection has the same shape in the batch-1 and batch-8 graphs —
/// and [`init_weights`] seeds values by *name*, so per-session weight
/// stores of the same model always held byte-identical copies. This
/// arena hoists them into one [`SharedSlab`] (the same aliasing
/// machinery as the serving engine's max-batch KV arena): each
/// specialization's session store maps its param tensors at this
/// arena's offsets, cutting serving weight memory by the number of
/// specializations and running `create`-time initialization exactly
/// once. After [`WeightArena::init`] the arena is **read-only** — no
/// compiled-graph task writes a param tensor — so cross-session
/// concurrent reads need no ordering (see the memory-model note in
/// `exec::store`).
pub struct WeightArena {
    slab: SharedSlab,
    /// param name → (element offset, numel). Layout follows the
    /// build graph's tensor order.
    offsets: HashMap<String, (usize, usize)>,
    /// Times [`WeightArena::init`] has run — the serving engine asserts
    /// this stays at 1 no matter how many specializations it builds.
    init_runs: AtomicU64,
}

impl WeightArena {
    /// Lay out every param tensor of `g` contiguously. Any batch-size
    /// specialization of the model works as the build graph — params
    /// are batch-independent.
    pub fn build(g: &CompGraph) -> WeightArena {
        let mut offsets = HashMap::new();
        let mut len = 0usize;
        for t in &g.tensors {
            if t.is_param {
                let prev = offsets.insert(t.name.clone(), (len, t.numel()));
                assert!(prev.is_none(), "duplicate param name {}", t.name);
                len += t.numel();
            }
        }
        WeightArena { slab: SharedSlab::new(len), offsets, init_runs: AtomicU64::new(0) }
    }

    /// Handle to the backing slab.
    pub fn slab(&self) -> SharedSlab {
        self.slab.clone()
    }

    /// Total elements across all params.
    pub fn len(&self) -> usize {
        self.slab.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slab.is_empty()
    }

    /// Times [`WeightArena::init`] has run.
    pub fn init_runs(&self) -> u64 {
        self.init_runs.load(Ordering::Relaxed)
    }

    /// Alias list mapping every param tensor of `g` (a batch-size
    /// specialization of the build model) into this arena, for
    /// [`TensorStore::new_with_aliases`]. Panics if `g` carries a param
    /// this arena does not know or whose size disagrees — weights are
    /// batch-independent, so every specialization must match exactly.
    pub fn aliases_for(&self, g: &CompGraph) -> Vec<(TensorId, SharedSlab, usize)> {
        g.tensors
            .iter()
            .filter(|t| t.is_param)
            .map(|t| {
                let &(off, numel) = self
                    .offsets
                    .get(&t.name)
                    .unwrap_or_else(|| panic!("weight arena has no param {}", t.name));
                assert_eq!(
                    numel,
                    t.numel(),
                    "param {} size differs across specializations",
                    t.name
                );
                (t.id, self.slab.clone(), off)
            })
            .collect()
    }

    /// Synthesize every param of `g` into the arena — same name-seeded
    /// values as [`init_weights`], written **once** for all aliasing
    /// sessions. Host staging: callers run this before any kernel
    /// exists (the serving engine does it at `create`).
    pub fn init(&self, g: &CompGraph, seed: u64) {
        for t in &g.tensors {
            if !t.is_param {
                continue;
            }
            let &(off, numel) = self
                .offsets
                .get(&t.name)
                .unwrap_or_else(|| panic!("weight arena has no param {}", t.name));
            assert_eq!(numel, t.numel(), "param {} size differs from build graph", t.name);
            self.slab.write(off, &synth_param(&t.name, numel, seed));
        }
        self.init_runs.fetch_add(1, Ordering::Relaxed);
    }
}

/// Write this iteration's token ids into a known tensor id — the
/// hot-path variant used by the serving engine, which resolves the id
/// once at session creation instead of per iteration.
pub fn set_ids_at(store: &TensorStore, t: crate::ops::TensorId, ids: &[i32]) {
    let vals: Vec<f32> = ids.iter().map(|&i| i as f32).collect();
    store.set(t, &vals);
}

/// Write this iteration's token ids into the store (by-name lookup).
/// A graph without the `token_ids` input is a typed error, not a panic
/// — this runs on serving threads.
pub fn set_ids(g: &CompGraph, store: &TensorStore, ids: &[i32]) -> Result<(), ManifestError> {
    let t = g
        .tensor_by_name("token_ids")
        .ok_or_else(|| ManifestError::MissingTensor { name: "token_ids".into() })?;
    set_ids_at(store, t.id, ids);
    Ok(())
}

/// Fetch the logits at a known tensor id (hot-path variant; the engine
/// reads them zero-copy via `TensorStore::view` instead).
pub fn logits_at(store: &TensorStore, t: crate::ops::TensorId) -> Vec<f32> {
    store.get(t)
}

/// Fetch the logits produced by the last iteration (by-name lookup).
/// A graph without the `lm_head` output is a typed error, not a panic.
pub fn get_logits(g: &CompGraph, store: &TensorStore) -> Result<Vec<f32>, ManifestError> {
    let t = g
        .tensor_by_name("lm_head")
        .ok_or_else(|| ManifestError::MissingTensor { name: "lm_head".into() })?;
    Ok(logits_at(store, t.id))
}

/// Run one decode iteration on the resident persistent megakernel with
/// real numerics. (One-shot validation paths use this too — PR 2
/// retired the scoped `MegaKernel` from the real-numerics path; it
/// survives only as the launch-overhead baseline.)
pub fn run_iteration(
    kernel: &mut PersistentMegaKernel,
    exec: &TileExecutor,
    cur_len: usize,
) -> Result<RunReport, String> {
    exec.set_cur_len(cur_len);
    let report = kernel.run(exec)?;
    if let Some(e) = exec.take_error() {
        return Err(e.into());
    }
    Ok(report)
}

/// Run the fused reference decode artifact on the same store state and
/// return the logits. Cache inputs are read *as stored* — on entry to an
/// iteration they contain tokens `0..cur_len` (the reference appends the
/// current token itself, mirroring `KvAppend`).
pub fn run_reference(
    manifest: &Manifest,
    pool: &ExecPool,
    g: &CompGraph,
    store: &TensorStore,
    batch: usize,
    ids: &[i32],
    cur_len: usize,
) -> Result<Vec<f32>, String> {
    let m = manifest.model;
    // a tensor lookup miss is a typed ManifestError converted through
    // the String shim — never a panic on a serving thread.
    let by_name = |n: &str| -> Result<Value, String> {
        let t = g
            .tensor_by_name(n)
            .ok_or_else(|| String::from(ManifestError::MissingTensor { name: n.to_string() }))?;
        Ok(Value::F32(store.get(t.id)))
    };
    let mut inputs: Vec<Value> = Vec::new();
    inputs.push(Value::I32(ids.to_vec()));
    for l in 0..m.layers {
        inputs.push(by_name(&format!("l{l}.kcache"))?);
    }
    for l in 0..m.layers {
        inputs.push(by_name(&format!("l{l}.vcache"))?);
    }
    inputs.push(Value::I32(vec![cur_len as i32]));
    inputs.push(by_name("embed.weight")?);
    for l in 0..m.layers {
        inputs.push(by_name(&format!("l{l}.ln1.weight"))?);
        inputs.push(by_name(&format!("l{l}.wqkv"))?);
        inputs.push(by_name(&format!("l{l}.wo"))?);
        inputs.push(by_name(&format!("l{l}.ln2.weight"))?);
        inputs.push(by_name(&format!("l{l}.w_gate_up"))?);
        inputs.push(by_name(&format!("l{l}.w_down"))?);
    }
    inputs.push(by_name("final_norm.weight")?);
    inputs.push(by_name("lm_head.weight")?);
    let name = format!("ref_decode_b{batch}");
    let out = pool.execute_by_name(&name, inputs)?;
    out.into_iter().next().ok_or_else(|| format!("{name}: empty result tuple"))
}

/// Argmax over a logits row.
pub fn argmax(row: &[f32]) -> usize {
    row.iter().enumerate().max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).map(|(i, _)| i).unwrap_or(0)
}

/// Convenience bundle for examples/tests: pool + graph + store for a
/// given batch size. Graph, store, and pool are `Arc`-shared so a
/// session can hand out a resident [`PersistentMegaKernel`] and a
/// long-lived [`OwningTileExecutor`] without borrow gymnastics.
pub struct RealSession {
    pub manifest: Manifest,
    pub pool: Arc<ExecPool>,
    pub batch: usize,
    pub compiled: Arc<CompiledGraph>,
    pub store: Arc<TensorStore>,
}

impl RealSession {
    /// Session on the environment-selected backend (`MPK_BACKEND`,
    /// defaulting to native CPU — so this works in a bare container).
    pub fn create(batch: usize, pool_threads: usize, seed: u64) -> Result<RealSession, String> {
        Self::create_with(batch, pool_threads, seed, BackendKind::from_env())
    }

    /// Session on an explicit backend. Artifact-free backends fall back
    /// to the compiled-in manifest when no artifacts dir exists.
    pub fn create_with(
        batch: usize,
        pool_threads: usize,
        seed: u64,
        kind: BackendKind,
    ) -> Result<RealSession, String> {
        let manifest = Manifest::resolve(&Manifest::default_dir(), kind)?;
        let compiled = Arc::new(compile_real(&manifest, batch)?);
        let store = Arc::new(TensorStore::new(&compiled.graph));
        init_weights(&compiled.graph, &store, seed);
        let pool = Arc::new(ExecPool::with_backend(manifest.clone(), pool_threads, kind)?);
        Ok(RealSession { manifest, pool, batch, compiled, store })
    }

    pub fn mega_config(&self, workers: usize, schedulers: usize) -> MegaConfig {
        MegaConfig { workers, schedulers, ..Default::default() }
    }

    /// A resident kernel over this session's graph (threads parked
    /// between iterations — the paper-faithful path).
    pub fn persistent_kernel(&self, workers: usize, schedulers: usize) -> PersistentMegaKernel {
        PersistentMegaKernel::new(self.compiled.clone(), self.mega_config(workers, schedulers))
    }

    /// A long-lived owning executor over this session's arena and pool.
    pub fn owning_executor(&self) -> OwningTileExecutor {
        OwningTileExecutor::new(self.compiled.clone(), self.store.clone(), self.pool.clone(), self.batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Batch-`b` tiny-model decode graph — no artifacts needed, so the
    /// weight-arena tests below run everywhere.
    fn tiny_graph(b: usize) -> CompGraph {
        build_decode_graph(
            &ModelConfig::tiny(),
            &GraphOptions { batch: b, kv_len: 15, dtype: DType::F32, ..Default::default() },
        )
    }

    #[test]
    fn weight_arena_matches_per_store_init() {
        // the arena's name-seeded values must be byte-identical to what
        // per-session init_weights writes, for every specialization.
        let g8 = tiny_graph(8);
        let arena = WeightArena::build(&g8);
        arena.init(&g8, 42);
        assert_eq!(arena.init_runs(), 1);
        for b in [1usize, 4] {
            let g = tiny_graph(b);
            let aliased = TensorStore::new_with_aliases(&g, arena.aliases_for(&g));
            let owned = TensorStore::new(&g);
            init_weights(&g, &owned, 42);
            for t in g.tensors.iter().filter(|t| t.is_param) {
                assert_eq!(aliased.view(t.id), owned.view(t.id), "param {} batch {b}", t.name);
            }
        }
    }

    #[test]
    fn weight_arena_is_shared_memory_not_a_copy() {
        let g2 = tiny_graph(2);
        let g4 = tiny_graph(4);
        let arena = WeightArena::build(&g4);
        arena.init(&g4, 7);
        let s2 = TensorStore::new_with_aliases(&g2, arena.aliases_for(&g2));
        let s4 = TensorStore::new_with_aliases(&g4, arena.aliases_for(&g4));
        let params: usize = g4.tensors.iter().filter(|t| t.is_param).map(|t| t.numel()).sum();
        assert_eq!(arena.len(), params);
        for t in g2.tensors.iter().filter(|t| t.is_param) {
            let t4 = g4.tensor_by_name(&t.name).unwrap().id;
            // same pointer, not merely equal values: one allocation.
            assert_eq!(
                s2.view(t.id).as_ptr(),
                s4.view(t4).as_ptr(),
                "param {} duplicated across sessions",
                t.name
            );
        }
        // neither session's own slab holds the weights any more.
        assert!(s2.owned_len() < params, "batch-2 store still packs weights");
        assert!(s4.owned_len() < params, "batch-4 store still packs weights");
        // a write through one session is visible to the other (staging
        // semantics — post-init the arena is read-only by contract).
        let e2 = g2.tensor_by_name("embed.weight").unwrap().id;
        let e4 = g4.tensor_by_name("embed.weight").unwrap().id;
        let mut v = s2.view(e2).to_vec();
        v[0] += 1.0;
        s2.set(e2, &v);
        assert_eq!(s4.view(e4)[0], v[0]);
    }

    #[test]
    #[should_panic(expected = "has no param")]
    fn weight_arena_rejects_foreign_graph() {
        let arena = WeightArena::build(&tiny_graph(1));
        let mut other = CompGraph::new();
        other.param("not.a.tiny.param", vec![2, 2], DType::F32);
        let _ = arena.aliases_for(&other);
    }

    #[test]
    fn mismatched_manifest_is_a_typed_error_not_a_panic() {
        let mut m = Manifest::builtin();
        m.model.layers = 2;
        let err = build_real_graph(&m, 1).unwrap_err();
        assert!(matches!(err, ManifestError::ModelMismatch { .. }), "got: {err}");
        // the rendered error carries both shapes for the operator.
        assert!(err.to_string().contains("does not match"), "got: {err}");
    }

    #[test]
    fn missing_tensor_lookups_are_typed_errors() {
        let g = CompGraph::new();
        let store = TensorStore::new(&g);
        let err = set_ids(&g, &store, &[1]).unwrap_err();
        assert_eq!(err, ManifestError::MissingTensor { name: "token_ids".into() });
        let err = get_logits(&g, &store).unwrap_err();
        assert_eq!(err, ManifestError::MissingTensor { name: "lm_head".into() });
    }

    #[test]
    fn real_graph_tiles_match_artifacts() {
        // needs only the manifest (graph/tile shapes), not a backend —
        // the compiled-in manifest carries the same tile geometry.
        let m = Manifest::builtin();
        let c = compile_real(&m, 2).unwrap();
        // every matmul task must be exactly tile_n wide.
        for t in &c.tgraph.tasks {
            if let crate::tgraph::TaskKind::Compute { kind: OpKind::MatMul, .. } = &t.kind {
                assert_eq!(t.out_region.extent(1), m.tile_n);
            }
            if let crate::tgraph::TaskKind::Compute { kind: OpKind::Attention { .. }, .. } = &t.kind {
                assert_eq!(t.out_region.extent(0), 1);
            }
        }
    }

    #[test]
    fn megakernel_matches_reference_logits_batch1() {
        let s = RealSession::create(1, 2, 42).unwrap();
        let mut kernel = s.persistent_kernel(4, 1);
        let exec = TileExecutor::new(&s.compiled.graph, &s.store, &s.pool, 1);
        // reference first (reads caches before KvAppend mutates them —
        // same values either way, but keep the clean order).
        set_ids(&s.compiled.graph, &s.store, &[7]).unwrap();
        let want = run_reference(&s.manifest, &s.pool, &s.compiled.graph, &s.store, 1, &[7], 0).unwrap();
        // the reference path allocates reply buffers (legacy execute);
        // the megakernel iteration itself must not: every task body
        // writes into its arena destination via execute_into.
        let boundary_allocs = s.pool.output_allocs();
        run_iteration(&mut kernel, &exec, 0).unwrap();
        assert_eq!(
            s.pool.output_allocs(),
            boundary_allocs,
            "a megakernel task received an allocated output buffer"
        );
        let got = get_logits(&s.compiled.graph, &s.store).unwrap();
        assert_eq!(got.len(), want.len());
        let max_err = got
            .iter()
            .zip(&want)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_err < 1e-3, "logits mismatch: max err {max_err}");
    }

    #[test]
    fn multi_step_decode_consistent_with_reference() {
        let s = RealSession::create(2, 2, 7).unwrap();
        // resident kernel re-armed across steps — the session outlives
        // each run, so the persistent front-end is the right tool.
        let mut kernel = s.persistent_kernel(4, 1);
        let exec = TileExecutor::new(&s.compiled.graph, &s.store, &s.pool, 2);
        let mut ids = vec![3i32, 11];
        for step in 0..3 {
            set_ids(&s.compiled.graph, &s.store, &ids).unwrap();
            let want =
                run_reference(&s.manifest, &s.pool, &s.compiled.graph, &s.store, 2, &ids, step).unwrap();
            let boundary_allocs = s.pool.output_allocs();
            run_iteration(&mut kernel, &exec, step).unwrap();
            assert_eq!(
                s.pool.output_allocs(),
                boundary_allocs,
                "step {step}: decode iteration allocated an output buffer"
            );
            let got = get_logits(&s.compiled.graph, &s.store).unwrap();
            let max_err =
                got.iter().zip(&want).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max);
            assert!(max_err < 1e-3, "step {step}: max err {max_err}");
            // greedy next tokens from the megakernel logits.
            let vocab = s.manifest.model.vocab;
            ids = (0..2).map(|r| argmax(&got[r * vocab..(r + 1) * vocab]) as i32).collect();
        }
        assert_eq!(kernel.epochs(), 3, "one epoch per decode step");
    }

    #[test]
    fn owning_executor_drives_decode() {
        // same decode through the owning executor (the serving-session
        // configuration) must match the borrowed executor.
        let s = RealSession::create(1, 2, 42).unwrap();
        let mut kernel = s.persistent_kernel(4, 1);
        let exec = s.owning_executor();
        set_ids(&s.compiled.graph, &s.store, &[7]).unwrap();
        exec.set_cur_len(0);
        kernel.run(&exec).unwrap();
        assert!(exec.take_error().is_none());
        let got = get_logits(&s.compiled.graph, &s.store).unwrap();

        let s2 = RealSession::create(1, 2, 42).unwrap();
        let mut k2 = s2.persistent_kernel(4, 1);
        let e2 = TileExecutor::new(&s2.compiled.graph, &s2.store, &s2.pool, 1);
        set_ids(&s2.compiled.graph, &s2.store, &[7]).unwrap();
        run_iteration(&mut k2, &e2, 0).unwrap();
        let want = get_logits(&s2.compiled.graph, &s2.store).unwrap();
        assert_eq!(got, want);
    }
}
