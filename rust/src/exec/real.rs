//! The real-numerics end-to-end path: build the tiny-model graph with
//! artifact-aligned partition hints, synthesize deterministic weights,
//! run decode iterations on the persistent megakernel, and validate
//! against the fused reference artifact.

use crate::exec::binder::{OwningTileExecutor, TileExecutor};
use crate::exec::store::TensorStore;
use crate::megakernel::{MegaConfig, PersistentMegaKernel, RunReport};
use crate::models::{build_decode_graph, GraphOptions, ModelConfig};
use crate::ops::{CompGraph, DType, OpKind};
use crate::runtime::pool::{ExecPool, Value};
use crate::runtime::Manifest;
use crate::tgraph::{compile, CompileOptions, CompiledGraph, DecomposeConfig};
use crate::util::XorShift64;
use std::sync::Arc;

/// Build the tiny-model decode graph whose tiles line up with the AOT
/// artifacts: matmuls tiled to `tile_n` columns, attention per request,
/// everything else whole-tensor.
pub fn build_real_graph(manifest: &Manifest, batch: usize) -> CompGraph {
    let cfg = ModelConfig::tiny();
    let m = manifest.model;
    assert_eq!(
        (m.layers, m.d_model, m.heads, m.kv_heads, m.head_dim, m.ffn, m.vocab),
        (cfg.layers, cfg.d_model, cfg.heads, cfg.kv_heads, cfg.head_dim, cfg.ffn, cfg.vocab),
        "rust ModelConfig::tiny() out of sync with python TinyConfig"
    );
    let mut g = build_decode_graph(
        &cfg,
        &GraphOptions {
            batch,
            kv_len: manifest.s_max - 1,
            dtype: DType::F32,
            // explicit KvAppend: the artifact set has a separate native
            // append step (the fused variant is for the perf graphs).
            fused_kv_append: false,
            ..Default::default()
        },
    );
    let tile_n = manifest.tile_n;
    for op in g.ops.iter_mut() {
        let out_shape = op.output;
        let _ = out_shape;
        op.partition_hint = Some(match op.kind {
            OpKind::MatMul => vec![1, 0], // cols filled below
            OpKind::Attention { .. } => vec![batch, 1],
            _ => vec![1; 2],
        });
    }
    // second pass with shapes in hand (borrow rules: shapes are on g).
    let shapes: Vec<Vec<usize>> = g.ops.iter().map(|o| g.tensors[o.output].shape.clone()).collect();
    for (op, shape) in g.ops.iter_mut().zip(shapes) {
        match op.kind {
            OpKind::MatMul => {
                assert_eq!(shape[1] % tile_n, 0, "{}: N={} not tileable", op.name, shape[1]);
                op.partition_hint = Some(vec![1, shape[1] / tile_n]);
            }
            OpKind::Attention { .. } => {}
            _ => {
                op.partition_hint = Some(vec![1; shape.len()]);
            }
        }
    }
    g
}

/// Compile the real graph for the megakernel.
pub fn compile_real(manifest: &Manifest, batch: usize) -> CompiledGraph {
    let g = build_real_graph(manifest, batch);
    compile(
        &g,
        &CompileOptions {
            decompose: DecomposeConfig { target_tasks: 8, min_tile_cols: 8 },
            ..Default::default()
        },
    )
}

/// Deterministically synthesize weights into the store (seeded per
/// tensor id): norm weights = 1, projections ~ U(-0.05, 0.05).
pub fn init_weights(g: &CompGraph, store: &TensorStore, seed: u64) {
    for t in &g.tensors {
        if !t.is_param {
            continue;
        }
        if t.name.contains("ln") || t.name.contains("norm") {
            let ones = vec![1.0; t.numel()];
            store.set(t.id, &ones);
        } else {
            // seed by *name* so the same weight tensor gets identical
            // values in every batch-size-specialized graph.
            let mut h: u64 = 0xcbf2_9ce4_8422_2325;
            for b in t.name.as_bytes() {
                h = (h ^ *b as u64).wrapping_mul(0x1000_0000_01b3);
            }
            let mut rng = XorShift64::new(seed ^ h);
            let w: Vec<f32> = (0..t.numel()).map(|_| rng.unit_f32() * 0.05).collect();
            store.set(t.id, &w);
        }
    }
}

/// Write this iteration's token ids into a known tensor id — the
/// hot-path variant used by the serving engine, which resolves the id
/// once at session creation instead of per iteration.
pub fn set_ids_at(store: &TensorStore, t: crate::ops::TensorId, ids: &[i32]) {
    let vals: Vec<f32> = ids.iter().map(|&i| i as f32).collect();
    store.set(t, &vals);
}

/// Write this iteration's token ids into the store (by-name lookup).
pub fn set_ids(g: &CompGraph, store: &TensorStore, ids: &[i32]) {
    let t = g.tensor_by_name("token_ids").expect("token_ids input");
    set_ids_at(store, t.id, ids);
}

/// Fetch the logits at a known tensor id (hot-path variant; the engine
/// reads them zero-copy via `TensorStore::view` instead).
pub fn logits_at(store: &TensorStore, t: crate::ops::TensorId) -> Vec<f32> {
    store.get(t)
}

/// Fetch the logits produced by the last iteration (by-name lookup).
pub fn get_logits(g: &CompGraph, store: &TensorStore) -> Vec<f32> {
    let t = g.tensor_by_name("lm_head").expect("lm_head output");
    logits_at(store, t.id)
}

/// Run one decode iteration on the resident persistent megakernel with
/// real numerics. (One-shot validation paths use this too — PR 2
/// retired the scoped `MegaKernel` from the real-numerics path; it
/// survives only as the launch-overhead baseline.)
pub fn run_iteration(
    kernel: &mut PersistentMegaKernel,
    exec: &TileExecutor,
    cur_len: usize,
) -> Result<RunReport, String> {
    exec.set_cur_len(cur_len);
    let report = kernel.run(exec)?;
    if let Some(e) = exec.take_error() {
        return Err(e);
    }
    Ok(report)
}

/// Run the fused reference decode artifact on the same store state and
/// return the logits. Cache inputs are read *as stored* — on entry to an
/// iteration they contain tokens `0..cur_len` (the reference appends the
/// current token itself, mirroring `KvAppend`).
pub fn run_reference(
    manifest: &Manifest,
    pool: &ExecPool,
    g: &CompGraph,
    store: &TensorStore,
    batch: usize,
    ids: &[i32],
    cur_len: usize,
) -> Result<Vec<f32>, String> {
    let m = manifest.model;
    let mut inputs: Vec<Value> = Vec::new();
    inputs.push(Value::I32(ids.to_vec()));
    for l in 0..m.layers {
        let t = g.tensor_by_name(&format!("l{l}.kcache")).unwrap();
        inputs.push(Value::F32(store.get(t.id)));
    }
    for l in 0..m.layers {
        let t = g.tensor_by_name(&format!("l{l}.vcache")).unwrap();
        inputs.push(Value::F32(store.get(t.id)));
    }
    inputs.push(Value::I32(vec![cur_len as i32]));
    let by_name = |n: &str| -> Value {
        Value::F32(store.get(g.tensor_by_name(n).unwrap_or_else(|| panic!("missing {n}")).id))
    };
    inputs.push(by_name("embed.weight"));
    for l in 0..m.layers {
        inputs.push(by_name(&format!("l{l}.ln1.weight")));
        inputs.push(by_name(&format!("l{l}.wqkv")));
        inputs.push(by_name(&format!("l{l}.wo")));
        inputs.push(by_name(&format!("l{l}.ln2.weight")));
        inputs.push(by_name(&format!("l{l}.w_gate_up")));
        inputs.push(by_name(&format!("l{l}.w_down")));
    }
    inputs.push(by_name("final_norm.weight"));
    inputs.push(by_name("lm_head.weight"));
    let out = pool.execute_by_name(&format!("ref_decode_b{batch}"), inputs)?;
    Ok(out.into_iter().next().unwrap())
}

/// Argmax over a logits row.
pub fn argmax(row: &[f32]) -> usize {
    row.iter().enumerate().max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).map(|(i, _)| i).unwrap_or(0)
}

/// Convenience bundle for examples/tests: pool + graph + store for a
/// given batch size. Graph, store, and pool are `Arc`-shared so a
/// session can hand out a resident [`PersistentMegaKernel`] and a
/// long-lived [`OwningTileExecutor`] without borrow gymnastics.
pub struct RealSession {
    pub manifest: Manifest,
    pub pool: Arc<ExecPool>,
    pub batch: usize,
    pub compiled: Arc<CompiledGraph>,
    pub store: Arc<TensorStore>,
}

impl RealSession {
    pub fn create(batch: usize, pool_threads: usize, seed: u64) -> Result<RealSession, String> {
        let manifest = Manifest::load(&Manifest::default_dir())?;
        let compiled = Arc::new(compile_real(&manifest, batch));
        let store = Arc::new(TensorStore::new(&compiled.graph));
        init_weights(&compiled.graph, &store, seed);
        let pool = Arc::new(ExecPool::new(manifest.clone(), pool_threads)?);
        Ok(RealSession { manifest, pool, batch, compiled, store })
    }

    pub fn mega_config(&self, workers: usize, schedulers: usize) -> MegaConfig {
        MegaConfig { workers, schedulers, ..Default::default() }
    }

    /// A resident kernel over this session's graph (threads parked
    /// between iterations — the paper-faithful path).
    pub fn persistent_kernel(&self, workers: usize, schedulers: usize) -> PersistentMegaKernel {
        PersistentMegaKernel::new(self.compiled.clone(), self.mega_config(workers, schedulers))
    }

    /// A long-lived owning executor over this session's arena and pool.
    pub fn owning_executor(&self) -> OwningTileExecutor {
        OwningTileExecutor::new(self.compiled.clone(), self.store.clone(), self.pool.clone(), self.batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn have_artifacts() -> bool {
        Manifest::load(&Manifest::default_dir()).is_ok()
    }

    #[test]
    fn real_graph_tiles_match_artifacts() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&Manifest::default_dir()).unwrap();
        let c = compile_real(&m, 2);
        // every matmul task must be exactly tile_n wide.
        for t in &c.tgraph.tasks {
            if let crate::tgraph::TaskKind::Compute { kind: OpKind::MatMul, .. } = &t.kind {
                assert_eq!(t.out_region.extent(1), m.tile_n);
            }
            if let crate::tgraph::TaskKind::Compute { kind: OpKind::Attention { .. }, .. } = &t.kind {
                assert_eq!(t.out_region.extent(0), 1);
            }
        }
    }

    #[test]
    fn megakernel_matches_reference_logits_batch1() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let s = RealSession::create(1, 2, 42).unwrap();
        let mut kernel = s.persistent_kernel(4, 1);
        let exec = TileExecutor::new(&s.compiled.graph, &s.store, &s.pool, 1);
        // reference first (reads caches before KvAppend mutates them —
        // same values either way, but keep the clean order).
        set_ids(&s.compiled.graph, &s.store, &[7]);
        let want = run_reference(&s.manifest, &s.pool, &s.compiled.graph, &s.store, 1, &[7], 0).unwrap();
        run_iteration(&mut kernel, &exec, 0).unwrap();
        let got = get_logits(&s.compiled.graph, &s.store);
        assert_eq!(got.len(), want.len());
        let max_err = got
            .iter()
            .zip(&want)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_err < 1e-3, "logits mismatch: max err {max_err}");
    }

    #[test]
    fn multi_step_decode_consistent_with_reference() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let s = RealSession::create(2, 2, 7).unwrap();
        // resident kernel re-armed across steps — the session outlives
        // each run, so the persistent front-end is the right tool.
        let mut kernel = s.persistent_kernel(4, 1);
        let exec = TileExecutor::new(&s.compiled.graph, &s.store, &s.pool, 2);
        let mut ids = vec![3i32, 11];
        for step in 0..3 {
            set_ids(&s.compiled.graph, &s.store, &ids);
            let want =
                run_reference(&s.manifest, &s.pool, &s.compiled.graph, &s.store, 2, &ids, step).unwrap();
            run_iteration(&mut kernel, &exec, step).unwrap();
            let got = get_logits(&s.compiled.graph, &s.store);
            let max_err =
                got.iter().zip(&want).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max);
            assert!(max_err < 1e-3, "step {step}: max err {max_err}");
            // greedy next tokens from the megakernel logits.
            let vocab = s.manifest.model.vocab;
            ids = (0..2).map(|r| argmax(&got[r * vocab..(r + 1) * vocab]) as i32).collect();
        }
        assert_eq!(kernel.epochs(), 3, "one epoch per decode step");
    }

    #[test]
    fn owning_executor_drives_decode() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        // same decode through the owning executor (the serving-session
        // configuration) must match the borrowed executor.
        let s = RealSession::create(1, 2, 42).unwrap();
        let mut kernel = s.persistent_kernel(4, 1);
        let exec = s.owning_executor();
        set_ids(&s.compiled.graph, &s.store, &[7]);
        exec.set_cur_len(0);
        kernel.run(&exec).unwrap();
        assert!(exec.take_error().is_none());
        let got = get_logits(&s.compiled.graph, &s.store);

        let s2 = RealSession::create(1, 2, 42).unwrap();
        let mut k2 = s2.persistent_kernel(4, 1);
        let e2 = TileExecutor::new(&s2.compiled.graph, &s2.store, &s2.pool, 1);
        set_ids(&s2.compiled.graph, &s2.store, &[7]);
        run_iteration(&mut k2, &e2, 0).unwrap();
        let want = get_logits(&s2.compiled.graph, &s2.store);
        assert_eq!(got, want);
    }
}
