//! `mpk` CLI — the leader entrypoint.
//!
//! Subcommands:
//!   compile  <model> [--batch N] [--gpu NAME]   compiler-stage stats
//!   simulate <model> [--batch N] [--gpu NAME]   MPK vs baselines on a roofline
//!   verify   [model] [--batch N] [--gpu NAME] [--granularity G] [--mutations N]
//!            static race/deadlock verification of the compiled tGraphs
//!   serve    [--requests N] [--batch N] [--backend cpu|pjrt]
//!            [--paged [--block-tokens B] [--prefill-chunk E]]
//!            real-numerics serving (native CPU backend by default; no artifacts needed);
//!            --paged turns on the block-granular KV pool with copy-on-write
//!            prefix sharing, --prefill-chunk adds chunked-prefill epochs
//!   serve    --listen ADDR [--requests N]       TCP serving (wire protocol + graceful drain)
//!   models                                      list known model configs

use mpk::megakernel::MegaConfig;
use mpk::models::{build_decode_graph, GraphOptions, ModelConfig};
use mpk::runtime::BackendKind;
use mpk::serving::mock::MockEngine;
use mpk::serving::{
    Request, ServeEngine, ServeServer, ServeTransport, ServerConfig, SubmitOptions,
    TransportClient, TransportConfig,
};
use mpk::sim::{simulate_baseline, simulate_megakernel, BaselineSystem, GpuSpec, SimOptions};
use mpk::tgraph::{
    compile, compile_verified, mutation_sweep, CompileOptions, DecomposeConfig, DepGranularity,
};
use std::time::Duration;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "models" => {
            for m in ModelConfig::paper_models().iter().chain(std::iter::once(&ModelConfig::tiny())) {
                println!(
                    "{:<16} {} layers, d={}, {}q/{}kv heads, ~{:.1}B params{}",
                    m.name,
                    m.layers,
                    m.d_model,
                    m.heads,
                    m.kv_heads,
                    m.param_count() as f64 / 1e9,
                    if m.moe.is_some() { " (MoE)" } else { "" }
                );
            }
        }
        "compile" | "simulate" => {
            let model = flag_pos(&args, 1).unwrap_or_else(|| "Qwen3-1.7B".into());
            let batch: usize = flag(&args, "--batch").and_then(|v| v.parse().ok()).unwrap_or(1);
            let gpu = GpuSpec::by_name(&flag(&args, "--gpu").unwrap_or_else(|| "B200".into()))
                .expect("unknown GPU (A100/H100/B200)");
            let cfg = ModelConfig::by_name(&model).expect("unknown model; see `mpk models`");
            let g = build_decode_graph(&cfg, &GraphOptions { batch, kv_len: 512, ..Default::default() });
            let c = compile(
                &g,
                &CompileOptions {
                    decompose: DecomposeConfig { target_tasks: gpu.workers, min_tile_cols: 8 },
                    ..Default::default()
                },
            );
            let s = c.stats();
            println!("{} @ batch {batch} on {}:", cfg.name, gpu.name);
            println!("  ops {} | tasks {} ({:.1}/op) | events {}", s.ops, s.tasks, s.tasks_per_op, s.events);
            println!(
                "  fusion {:.0}x | linearization {:.1}x | normalization overhead {:.2}%",
                s.fusion_reduction,
                s.lin_reduction,
                s.norm_overhead * 100.0
            );
            if cmd == "simulate" {
                let mpk = simulate_megakernel(&c, &gpu, &SimOptions::default()).makespan_us;
                println!("  MPK            {:>10.1} µs/iter", mpk);
                for sys in BaselineSystem::all() {
                    let b = simulate_baseline(&c, &gpu, &sys, None);
                    println!("  {:<14} {:>10.1} µs/iter ({:.2}x vs MPK)", sys.name, b, b / mpk);
                }
            }
        }
        "verify" => {
            let batch: usize = flag(&args, "--batch").and_then(|v| v.parse().ok()).unwrap_or(1);
            let gpu = GpuSpec::by_name(&flag(&args, "--gpu").unwrap_or_else(|| "B200".into()))
                .expect("unknown GPU (A100/H100/B200)");
            let mutations: usize =
                flag(&args, "--mutations").and_then(|v| v.parse().ok()).unwrap_or(16);
            let grans: Vec<DepGranularity> = match flag(&args, "--granularity").as_deref() {
                None | Some("all") => vec![
                    DepGranularity::Fine,
                    DepGranularity::CoarseCollectives,
                    DepGranularity::CoarseAll,
                ],
                Some("fine") => vec![DepGranularity::Fine],
                Some("coarse-collectives") => vec![DepGranularity::CoarseCollectives],
                Some("coarse-all") => vec![DepGranularity::CoarseAll],
                Some(g) => panic!("unknown granularity {g} (fine/coarse-collectives/coarse-all/all)"),
            };
            let models: Vec<ModelConfig> = match flag_pos(&args, 1) {
                Some(m) => vec![ModelConfig::by_name(&m).expect("unknown model; see `mpk models`")],
                None => {
                    let mut v = ModelConfig::paper_models();
                    v.push(ModelConfig::tiny());
                    v
                }
            };
            let mut failed = false;
            for cfg in &models {
                let g = build_decode_graph(
                    cfg,
                    &GraphOptions { batch, kv_len: 512, ..Default::default() },
                );
                for &gran in &grans {
                    let opt = CompileOptions {
                        decompose: DecomposeConfig { target_tasks: gpu.workers, min_tile_cols: 8 },
                        granularity: gran,
                        ..Default::default()
                    };
                    let (c, report) = compile_verified(&g, &opt);
                    // derived Debug ignores width padding; pre-render.
                    println!("{:<16} {:<18} {}", cfg.name, format!("{gran:?}"), report.summary());
                    if !report.is_clean() {
                        failed = true;
                        println!("{}", report.render(16));
                    }
                    if mutations > 0 {
                        let sweep = mutation_sweep(&c, mutations, 0xC0FFEE);
                        println!(
                            "{:<16} {:<18} mutation sweep: {}/{} caught ({:.0}%)",
                            "", "",
                            sweep.caught,
                            sweep.total,
                            sweep.catch_rate() * 100.0
                        );
                        if sweep.catch_rate() < 0.95 {
                            failed = true;
                            for m in &sweep.survivors {
                                println!("  survivor: {m}");
                            }
                        }
                    }
                }
            }
            if failed {
                eprintln!("mpk verify: FAILED (violations or mutation survivors above)");
                std::process::exit(1);
            }
            println!("mpk verify: OK ({} model(s) × {} granularit(ies))", models.len(), grans.len());
        }
        "serve" => {
            let n: usize = flag(&args, "--requests").and_then(|v| v.parse().ok()).unwrap_or(8);
            let batch: usize = flag(&args, "--batch").and_then(|v| v.parse().ok()).unwrap_or(4);
            let backend = parse_backend(&args);
            if let Some(addr) = flag(&args, "--listen") {
                serve_listen(&addr, n, batch, backend);
                return;
            }
            let mega = MegaConfig { workers: 6, schedulers: 2, ..Default::default() };
            let paged = has_flag(&args, "--paged");
            let block_tokens: usize =
                flag(&args, "--block-tokens").and_then(|v| v.parse().ok()).unwrap_or(8);
            let prefill_chunk: usize =
                flag(&args, "--prefill-chunk").and_then(|v| v.parse().ok()).unwrap_or(0);
            let mut e = ServeEngine::builder()
                .max_batch(batch)
                .pool_threads(3)
                .seed(42)
                .mega(mega)
                .backend(backend)
                .paged_kv(paged)
                .kv_block_tokens(block_tokens)
                .prefill_chunk(prefill_chunk)
                .build()
                .expect(
                    "engine build failed (the cpu backend needs no artifacts; \
                     pjrt needs `make artifacts` and a vendored PJRT build; \
                     --paged requires the cpu backend)",
                );
            println!("backend: {}", backend.name());
            if paged {
                println!(
                    "kv: paged, {}-token blocks, prefill chunk {}",
                    block_tokens, prefill_chunk
                );
            }
            // stream: half the wave up front, the rest submitted
            // mid-flight while earlier requests are still decoding.
            let prompt_for = |i: u64| -> Vec<i32> { (0..3).map(|t| 1 + (i as i32 * 13 + t) % 500).collect() };
            let mut next = 0u64;
            while next < (n as u64).div_ceil(2) {
                e.submit(Request::new(next, prompt_for(next), 6)).expect("request within max_seq");
                next += 1;
            }
            let mut done = 0usize;
            while e.has_work() {
                let outcome = e.step().expect("step");
                for ev in &outcome.events {
                    if let Some(reason) = ev.finish {
                        done += 1;
                        println!("req {:>3} finished ({reason:?})", ev.request);
                    }
                }
                // online admission: trickle the remaining requests in.
                if next < n as u64 {
                    e.submit(Request::new(next, prompt_for(next), 6)).expect("request within max_seq");
                    next += 1;
                }
            }
            let kv = e.kv_status();
            let stats = e.take_stats();
            if paged {
                println!(
                    "kv pool: {}/{} blocks free | {} shared | {} cow copies | {} prefix hits | \
                     {} prefill chunks",
                    kv.blocks_free,
                    kv.blocks_total,
                    kv.blocks_shared,
                    kv.blocks_cowed,
                    kv.prefix_hits,
                    kv.prefill_chunks
                );
            }
            println!(
                "{done} requests | {} tokens | {} iters | {:?} busy / {:?} wall | {:.1} tok/s | \
                 p50 iter {:?} | ttft p50 {:?}",
                stats.tokens_generated,
                stats.iterations,
                stats.busy,
                stats.total,
                stats.throughput_tok_s(),
                stats.p50_latency(),
                stats.ttft_p50()
            );
        }
        _ => {
            println!("mpk — mega-kernelizing tensor programs (see README.md)");
            println!("usage: mpk <models|compile|simulate|verify|serve> [args]");
            println!("  mpk compile Qwen3-8B --batch 1 --gpu B200");
            println!("  mpk simulate Qwen3-1.7B --batch 4 --gpu A100");
            println!("  mpk verify [model] --granularity all --mutations 16");
            println!("      static race/deadlock check of every compiled tGraph");
            println!("      (+ a seeded mutation sweep proving the analyzer bites);");
            println!("      nonzero exit on any violation or mutation survivor");
            println!("  mpk serve --requests 8 --batch 4 [--backend cpu|pjrt]");
            println!("      cpu (default) runs the native backend, no artifacts needed;");
            println!("      pjrt needs `make artifacts` and a vendored PJRT build");
            println!("  mpk serve --paged [--block-tokens 8] [--prefill-chunk 2]");
            println!("      block-granular KV pool with copy-on-write prefix sharing");
            println!("      and chunked prefill (cpu backend only)");
            println!("  mpk serve --listen 127.0.0.1:7171 --requests 8");
        }
    }
}

/// `serve --listen ADDR`: put the server behind the TCP transport,
/// drive a demo wave through a loopback wire client (the same frames a
/// remote client would send), then drain gracefully. Uses the
/// real-numerics engine on the selected backend (the CPU backend works
/// on any machine) and falls back to the engine-free mock only if even
/// that fails, so the wire path is demonstrable everywhere.
fn serve_listen(addr: &str, n: usize, batch: usize, backend: BackendKind) {
    let mega = MegaConfig { workers: 6, schedulers: 2, ..Default::default() };
    let server = match ServeServer::spawn(
        ServeEngine::builder().max_batch(batch).pool_threads(3).seed(42).mega(mega).backend(backend),
        ServerConfig::default(),
    ) {
        Ok(s) => {
            println!("engine: real numerics ({} backend)", backend.name());
            s
        }
        Err(e) => {
            println!("engine: engine-free mock ({e})");
            ServeServer::spawn_with(MockEngine::new(batch.max(1)), ServerConfig::default())
        }
    };
    let transport = ServeTransport::bind(addr, server, TransportConfig::default())
        .expect("bind listen address");
    println!(
        "listening on {} (wire protocol v{})",
        transport.local_addr(),
        mpk::serving::wire::WIRE_VERSION
    );

    // demo wave over loopback: every request crosses the full wire
    // path — encode, socket, reader, server RPC, pump, writer, decode.
    let mut client = TransportClient::connect(transport.local_addr()).expect("loopback connect");
    for i in 0..n as u64 {
        let prompt: Vec<i32> = (0..3).map(|t| 1 + (i as i32 * 13 + t) % 500).collect();
        match client.run(i + 1, prompt, 6, SubmitOptions::default()) {
            Ok((tokens, finish)) => {
                println!("req {:>3} -> {} tokens over the wire ({finish:?})", i + 1, tokens.len());
            }
            Err(e) => println!("req {:>3} -> {e}", i + 1),
        }
    }

    let report = transport.drain(Duration::from_secs(5));
    let m = &report.transport;
    println!(
        "drained in {:?} ({} forced) | {} conns | {} submitted / {} finished / {} rejected | \
         {} frames out / {} in",
        report.elapsed,
        report.forced,
        m.conns_accepted,
        m.requests_submitted,
        report.server.finished,
        m.requests_rejected,
        m.frames_sent,
        m.frames_received,
    );
}

/// `--backend cpu|pjrt`; falls back to `MPK_BACKEND` / the CPU default
/// when the flag is absent, and exits with a usage message on an
/// unknown name instead of silently serving on the wrong backend.
fn parse_backend(args: &[String]) -> BackendKind {
    match flag(args, "--backend") {
        None => BackendKind::from_env(),
        Some(v) => BackendKind::parse(&v).unwrap_or_else(|| {
            eprintln!("unknown backend {v:?} (expected cpu or pjrt)");
            std::process::exit(2);
        }),
    }
}

fn flag(args: &[String], name: &str) -> Option<String> {
    args.iter().position(|a| a == name).and_then(|i| args.get(i + 1).cloned())
}

/// Presence-only flag (no value), e.g. `--paged`.
fn has_flag(args: &[String], name: &str) -> bool {
    args.iter().any(|a| a == name)
}

fn flag_pos(args: &[String], idx: usize) -> Option<String> {
    args.get(idx).filter(|a| !a.starts_with("--")).cloned()
}
