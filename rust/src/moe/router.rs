//! Expert routing workload generation (§6.4).
//!
//! The number of tokens routed to each expert is known only at runtime;
//! skewed routing is exactly what breaks static SM partitioning. This
//! module synthesizes routing distributions (uniform → heavily skewed)
//! with a deterministic RNG so every balancer sees identical workloads.

use crate::util::XorShift64;

/// A routing outcome: tokens assigned to each expert for one MoE layer.
#[derive(Clone, Debug)]
pub struct Routing {
    /// tokens_per_expert\[e\] = number of (token, slot) pairs routed to e.
    pub tokens_per_expert: Vec<usize>,
    pub batch: usize,
    pub top_k: usize,
}

impl Routing {
    pub fn total_assignments(&self) -> usize {
        self.tokens_per_expert.iter().sum()
    }

    /// Experts with at least one token (whose weights must stream).
    pub fn activated(&self) -> usize {
        self.tokens_per_expert.iter().filter(|&&t| t > 0).count()
    }

    /// Max over experts — the static balancer's bottleneck.
    pub fn max_load(&self) -> usize {
        self.tokens_per_expert.iter().copied().max().unwrap_or(0)
    }
}

/// Routing skew profile.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Skew {
    /// Every token picks its top-k uniformly.
    Uniform,
    /// Zipf-like preference: a few hot experts absorb most tokens (the
    /// regime where the paper's static strategy collapses).
    Zipf(f64),
}

/// Simulate routing of `batch` tokens, each to `top_k` distinct experts.
pub fn route(batch: usize, experts: usize, top_k: usize, skew: Skew, seed: u64) -> Routing {
    let mut rng = XorShift64::new(seed);
    let mut tokens = vec![0usize; experts];
    // expert popularity weights.
    let weights: Vec<f64> = match skew {
        Skew::Uniform => vec![1.0; experts],
        Skew::Zipf(a) => (0..experts).map(|i| 1.0 / ((i + 1) as f64).powf(a)).collect(),
    };
    let total_w: f64 = weights.iter().sum();
    for _ in 0..batch {
        let mut chosen = Vec::with_capacity(top_k);
        let mut guard = 0;
        while chosen.len() < top_k.min(experts) && guard < 10_000 {
            guard += 1;
            let mut x = rng.f64() * total_w;
            let mut e = 0;
            for (i, &w) in weights.iter().enumerate() {
                x -= w;
                if x <= 0.0 {
                    e = i;
                    break;
                }
            }
            if !chosen.contains(&e) {
                chosen.push(e);
            }
        }
        for e in chosen {
            tokens[e] += 1;
        }
    }
    Routing { tokens_per_expert: tokens, batch, top_k }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_routing_conserves_assignments() {
        let r = route(16, 128, 8, Skew::Uniform, 1);
        assert_eq!(r.total_assignments(), 16 * 8);
        assert!(r.activated() <= 128);
    }

    #[test]
    fn topk_experts_distinct_per_token() {
        // with batch 1, exactly top_k experts get one token each.
        let r = route(1, 128, 8, Skew::Zipf(1.2), 7);
        assert_eq!(r.total_assignments(), 8);
        assert_eq!(r.max_load(), 1);
        assert_eq!(r.activated(), 8);
    }

    #[test]
    fn zipf_is_more_skewed_than_uniform() {
        let u = route(64, 128, 8, Skew::Uniform, 3);
        let z = route(64, 128, 8, Skew::Zipf(1.5), 3);
        assert!(z.max_load() > u.max_load(), "zipf {} vs uniform {}", z.max_load(), u.max_load());
    }

    #[test]
    fn deterministic_per_seed() {
        let a = route(32, 64, 4, Skew::Zipf(1.0), 42);
        let b = route(32, 64, 4, Skew::Zipf(1.0), 42);
        assert_eq!(a.tokens_per_expert, b.tokens_per_expert);
    }
}
