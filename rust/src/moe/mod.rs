//! Mixture-of-experts support (§6.4): routing workloads and the
//! static / hybrid / dynamic workload balancers of Figure 10.
pub mod balance;
pub mod router;

pub use balance::{dynamic_us, hybrid_us, sglang_us, static_partition_us, MoeCost};
pub use router::{route, Routing, Skew};
