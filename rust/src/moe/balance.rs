//! MoE workload balancers (§6.4, Figure 10).
//!
//! Three strategies for executing one MoE block's expert GEMMs given a
//! runtime routing outcome:
//!
//! * **Static** — SM groups pre-assigned to experts; skew oversubscribes
//!   hot groups while others idle.
//! * **Hybrid (MPK)** — compile-time expert tasks + the runtime
//!   meta-tensor from topk-softmax: tasks split their token work evenly
//!   across SMs with one cheap refinement step.
//! * **Dynamic** — persistent grouped-GEMM: perfect balance but
//!   fine-grained synchronization on every tile.
//!
//! Plus the SGLang-class baseline: separate gather kernel (≈11 % of MoE
//! time at batch 1 per the paper) + kernel launches + monolithic-kernel
//! efficiency. All times in µs on a [`GpuSpec`] roofline.

use crate::models::MoeConfig;
use crate::moe::router::Routing;
use crate::sim::gpu::GpuSpec;

/// Modeled cost of one MoE block under a routing outcome.
#[derive(Clone, Copy, Debug)]
pub struct MoeCost {
    pub us: f64,
    /// Bytes streamed (weights of activated experts + activations).
    pub bytes: f64,
}

/// Per-expert work: weights stream once if activated; activations and
/// outputs scale with the expert's token count.
fn expert_bytes(cfg: &MoeConfig, d_model: usize, tokens: usize, elem: usize) -> f64 {
    if tokens == 0 {
        return 0.0;
    }
    let weight = (3 * d_model * cfg.expert_ffn * elem) as f64; // gate, up, down
    let act = (tokens * (2 * d_model + 3 * cfg.expert_ffn) * elem) as f64;
    weight + act
}

fn total_bytes(cfg: &MoeConfig, d_model: usize, r: &Routing, elem: usize) -> f64 {
    r.tokens_per_expert.iter().map(|&t| expert_bytes(cfg, d_model, t, elem)).sum()
}

/// Static partitioning: workers divided evenly into `groups` fixed SM
/// groups, experts assigned round-robin. Makespan = slowest group.
pub fn static_partition_us(
    cfg: &MoeConfig,
    d_model: usize,
    r: &Routing,
    gpu: &GpuSpec,
    groups: usize,
) -> MoeCost {
    let elem = 2;
    let groups = groups.clamp(1, gpu.workers);
    let per_group_workers = (gpu.workers / groups).max(1);
    let mut group_bytes = vec![0.0f64; groups];
    for (e, &t) in r.tokens_per_expert.iter().enumerate() {
        group_bytes[e % groups] += expert_bytes(cfg, d_model, t, elem);
    }
    let share = gpu.bw_share() * gpu.bw_eff_pipelined;
    let makespan = group_bytes
        .iter()
        .map(|b| b / (share * per_group_workers as f64))
        .fold(0.0f64, f64::max);
    MoeCost { us: makespan, bytes: total_bytes(cfg, d_model, r, elem) }
}

/// MPK hybrid: static task structure + runtime refinement from the
/// routing meta-tensor. Work spreads nearly evenly; each expert task
/// pays one event synchronization.
pub fn hybrid_us(cfg: &MoeConfig, d_model: usize, r: &Routing, gpu: &GpuSpec) -> MoeCost {
    let elem = 2;
    let bytes = total_bytes(cfg, d_model, r, elem);
    let share = gpu.bw_share() * gpu.bw_eff_pipelined;
    // even split across all workers, plus per-activated-expert dispatch
    // and one meta-tensor read.
    let even = bytes / (share * gpu.workers as f64);
    let sync = r.activated() as f64 * gpu.aot_check_us / gpu.workers as f64 + 0.5;
    // residual imbalance: the refinement splits at task granularity, not
    // perfectly — model 5% tail.
    MoeCost { us: even * 1.05 + sync, bytes }
}

/// Fully dynamic persistent grouped-GEMM: perfect balance, but every
/// tile claims work through a global atomic queue.
pub fn dynamic_us(cfg: &MoeConfig, d_model: usize, r: &Routing, gpu: &GpuSpec) -> MoeCost {
    let elem = 2;
    let bytes = total_bytes(cfg, d_model, r, elem);
    let share = gpu.bw_share() * gpu.bw_eff_pipelined;
    let even = bytes / (share * gpu.workers as f64);
    // fine-grained sync on every tile: ~1 atomic round-trip per tile of
    // 128 columns per expert.
    let tiles = r.activated() as f64 * (cfg.expert_ffn as f64 / 128.0).max(1.0) * 3.0;
    let sync = tiles * gpu.jit_dispatch_us / gpu.workers as f64 + 2.0;
    MoeCost { us: even + sync, bytes }
}

/// SGLang-class MoE: gather preprocessing kernel (≈11 % at batch 1,
/// amortizing with batch), grouped-GEMM kernel at monolithic efficiency,
/// plus kernel launches.
pub fn sglang_us(cfg: &MoeConfig, d_model: usize, r: &Routing, gpu: &GpuSpec) -> MoeCost {
    let elem = 2;
    let bytes = total_bytes(cfg, d_model, r, elem);
    let share = gpu.bw_share() * gpu.bw_eff_kernel;
    let gemm = bytes / (share * gpu.workers as f64);
    // gather cost: proportional to token traffic, calibrated to ~11% of
    // the MoE block at batch 1 (§6.4).
    let gather = 0.11 * gemm * (1.0 + 1.0 / r.batch as f64) / 2.0 + 1.0;
    // kernels: gather + topk + grouped gemm ×3 + combine.
    let launches = 6.0 * gpu.launch_us_graph;
    MoeCost { us: gemm + gather + launches, bytes }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::ModelConfig;
    use crate::moe::router::{route, Skew};

    fn setup(batch: usize, seed: u64) -> (MoeConfig, usize, Routing, GpuSpec) {
        let cfg = ModelConfig::qwen3_30b_a3b();
        let moe = cfg.moe.unwrap();
        let r = route(batch, moe.num_experts, moe.top_k, Skew::Zipf(1.2), seed);
        (moe, cfg.d_model, r, GpuSpec::b200())
    }

    #[test]
    fn hybrid_beats_static_under_skew() {
        for batch in [1usize, 4, 8, 16] {
            let (moe, d, r, gpu) = setup(batch, 11);
            let st = static_partition_us(&moe, d, &r, &gpu, 16);
            let hy = hybrid_us(&moe, d, &r, &gpu);
            assert!(hy.us <= st.us, "batch {batch}: hybrid {} > static {}", hy.us, st.us);
        }
    }

    #[test]
    fn hybrid_beats_sglang_in_paper_band() {
        // Figure 10: MPK-Hybrid over SGLang-MoE, roughly 1.1–2×.
        for batch in [1usize, 2, 4, 8, 16] {
            let (moe, d, r, gpu) = setup(batch, 5);
            let hy = hybrid_us(&moe, d, &r, &gpu);
            let sg = sglang_us(&moe, d, &r, &gpu);
            let speedup = sg.us / hy.us;
            assert!(
                (1.02..=2.5).contains(&speedup),
                "batch {batch}: speedup {speedup:.2}"
            );
        }
    }

    #[test]
    fn dynamic_pays_sync_overhead_vs_hybrid_at_small_batch() {
        let (moe, d, r, gpu) = setup(1, 9);
        let hy = hybrid_us(&moe, d, &r, &gpu);
        let dy = dynamic_us(&moe, d, &r, &gpu);
        assert!(dy.us > hy.us, "dynamic {} <= hybrid {}", dy.us, hy.us);
    }

    #[test]
    fn uniform_routing_narrows_static_gap() {
        let cfg = ModelConfig::qwen3_30b_a3b();
        let moe = cfg.moe.unwrap();
        let gpu = GpuSpec::b200();
        let skewed = route(16, moe.num_experts, moe.top_k, Skew::Zipf(1.5), 3);
        let uniform = route(16, moe.num_experts, moe.top_k, Skew::Uniform, 3);
        let gap = |r: &Routing| {
            static_partition_us(&moe, cfg.d_model, r, &gpu, 16).us
                / hybrid_us(&moe, cfg.d_model, r, &gpu).us
        };
        assert!(gap(&skewed) > gap(&uniform), "skew should widen the static gap");
    }

    #[test]
    fn bytes_scale_with_activated_experts() {
        let (moe, d, _, _) = setup(1, 1);
        let one = expert_bytes(&moe, d, 1, 2);
        let zero = expert_bytes(&moe, d, 0, 2);
        assert_eq!(zero, 0.0);
        assert!(one > (3 * d * moe.expert_ffn * 2) as f64 * 0.99);
    }
}
