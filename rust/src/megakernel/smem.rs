//! Paged shared-memory abstraction (§5.3).
//!
//! Shared memory (VMEM analogue on TPU) is split into fixed-size pages.
//! A task acquires pages up front, may acquire more while it has not yet
//! released any, and once it releases a page it may only release —
//! the *monotonic usage* rule that lets the runtime hand freed pages to
//! the next task's preload phase while the current task still computes.

/// Page size used in the paper's evaluation (32 KB on all GPUs).
pub const PAGE_BYTES: usize = 32 * 1024;

/// Per-worker page allocator.
#[derive(Debug)]
pub struct PagedSmem {
    total_pages: usize,
    free: Vec<usize>,
    /// Pages held per task id.
    held: std::collections::HashMap<usize, Vec<usize>>,
    /// Tasks that have released at least one page (monotonic rule).
    releasing: std::collections::HashSet<usize>,
}

/// Errors from the allocator.
#[derive(Debug, PartialEq, Eq)]
pub enum SmemError {
    /// Not enough free pages right now (caller should retry later — this
    /// is what delays a preload, not a failure).
    OutOfPages,
    /// A task attempted to acquire after releasing (monotonic violation).
    MonotonicViolation,
}

impl PagedSmem {
    pub fn new(total_pages: usize) -> Self {
        PagedSmem {
            total_pages,
            free: (0..total_pages).rev().collect(),
            held: Default::default(),
            releasing: Default::default(),
        }
    }

    /// Pages needed for `bytes` of scratch.
    pub fn pages_for(bytes: usize) -> usize {
        bytes.div_ceil(PAGE_BYTES)
    }

    pub fn free_pages(&self) -> usize {
        self.free.len()
    }

    pub fn total_pages(&self) -> usize {
        self.total_pages
    }

    /// Acquire `n` pages for `task`. All-or-nothing.
    pub fn acquire(&mut self, task: usize, n: usize) -> Result<Vec<usize>, SmemError> {
        if self.releasing.contains(&task) {
            return Err(SmemError::MonotonicViolation);
        }
        if self.free.len() < n {
            return Err(SmemError::OutOfPages);
        }
        let pages: Vec<usize> = (0..n).map(|_| self.free.pop().unwrap()).collect();
        self.held.entry(task).or_default().extend(&pages);
        Ok(pages)
    }

    /// Release `n` of the pages held by `task` (all of them if `n`
    /// exceeds the held count). After this the task may not acquire.
    pub fn release(&mut self, task: usize, n: usize) -> usize {
        let held = self.held.entry(task).or_default();
        let k = n.min(held.len());
        for _ in 0..k {
            self.free.push(held.pop().unwrap());
        }
        if held.is_empty() {
            self.held.remove(&task);
        }
        if k > 0 {
            self.releasing.insert(task);
        }
        k
    }

    /// Release everything held by `task` and clear its monotonic flag
    /// (the task is finished).
    pub fn finish(&mut self, task: usize) {
        if let Some(held) = self.held.remove(&task) {
            self.free.extend(held);
        }
        self.releasing.remove(&task);
        debug_assert!(self.free.len() <= self.total_pages);
    }

    /// Can the next task's preload start now? (§5.3 condition 2.)
    pub fn can_preload(&self, pages_needed: usize) -> bool {
        self.free.len() >= pages_needed
    }
}

/// Modeled shared-memory footprint (bytes) of a task — how many pages a
/// task of this kind/tile occupies while resident on an SM. Used both by
/// the allocator and by the simulator's pipelining condition.
pub fn task_smem_bytes(kind: &crate::tgraph::TaskKind, elem: usize) -> usize {
    use crate::ops::OpKind;
    use crate::tgraph::TaskKind as TK;
    match kind {
        TK::Compute { kind, .. } => match kind {
            // double-buffered K-slab of x and w tiles + accumulator.
            OpKind::MatMul => 3 * PAGE_BYTES,
            OpKind::Attention { head_dim, kv_heads, .. } => {
                // q tile + one KV chunk in flight + output accumulator.
                (2 * kv_heads * head_dim * 128 * elem).clamp(PAGE_BYTES, 4 * PAGE_BYTES)
            }
            OpKind::MoeExpertGemm { .. } => 3 * PAGE_BYTES,
            OpKind::Embedding | OpKind::RmsNorm | OpKind::Add | OpKind::SwiGLU | OpKind::KvAppend => PAGE_BYTES,
            OpKind::AllReduce { .. } => 2 * PAGE_BYTES,
            OpKind::MoeRoute { .. } | OpKind::MoeCombine { .. } => PAGE_BYTES,
        },
        TK::Transfer { .. } => PAGE_BYTES,
        TK::Dummy | TK::IterPrep => 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn acquire_release_cycle() {
        let mut s = PagedSmem::new(5);
        let p = s.acquire(1, 3).unwrap();
        assert_eq!(p.len(), 3);
        assert_eq!(s.free_pages(), 2);
        assert_eq!(s.release(1, 2), 2);
        assert_eq!(s.free_pages(), 4);
        s.finish(1);
        assert_eq!(s.free_pages(), 5);
    }

    #[test]
    fn monotonic_rule_enforced() {
        let mut s = PagedSmem::new(5);
        s.acquire(1, 2).unwrap();
        s.release(1, 1);
        assert_eq!(s.acquire(1, 1), Err(SmemError::MonotonicViolation));
        s.finish(1);
        // finished task may start a fresh acquire cycle.
        assert!(s.acquire(1, 1).is_ok());
    }

    #[test]
    fn out_of_pages_is_retryable() {
        let mut s = PagedSmem::new(2);
        s.acquire(1, 2).unwrap();
        assert_eq!(s.acquire(2, 1), Err(SmemError::OutOfPages));
        assert!(!s.can_preload(1));
        s.release(1, 1);
        assert!(s.can_preload(1));
        assert!(s.acquire(2, 1).is_ok());
    }

    #[test]
    fn pages_for_rounds_up() {
        assert_eq!(PagedSmem::pages_for(1), 1);
        assert_eq!(PagedSmem::pages_for(PAGE_BYTES), 1);
        assert_eq!(PagedSmem::pages_for(PAGE_BYTES + 1), 2);
        assert_eq!(PagedSmem::pages_for(0), 0);
    }

    #[test]
    fn no_page_leak_under_random_ops() {
        let mut rng = crate::util::XorShift64::new(9);
        let mut s = PagedSmem::new(7);
        for task in 0..200 {
            let n = rng.range(0, 4);
            if s.acquire(task, n).is_ok() {
                if rng.below(2) == 0 {
                    s.release(task, rng.range(0, n));
                }
            }
            s.finish(task);
            assert_eq!(s.free_pages(), 7, "leak after task {task}");
        }
    }
}
