//! Fixed-capacity lock-free queues — the device-memory circular buffers
//! of §6.1 ("event queues and task queues are implemented as circular
//! buffers ... enqueue and dequeue operations rely only on low-cost
//! atomicAdd instructions").
//!
//! [`MpmcQueue`] is the classic bounded MPMC ring (per-slot sequence
//! numbers, Vyukov-style): workers push activated events to schedulers,
//! and schedulers push JIT tasks to workers, without locks on the hot
//! path. The per-worker AOT queue needs no atomics at all: it is filled
//! once before launch and consumed by a single worker ([`AotQueue`]).

use std::cell::UnsafeCell;
use std::mem::MaybeUninit;
use std::sync::atomic::{AtomicUsize, Ordering};

struct Slot<T> {
    seq: AtomicUsize,
    val: UnsafeCell<MaybeUninit<T>>,
}

/// Bounded multi-producer multi-consumer queue.
pub struct MpmcQueue<T> {
    buf: Box<[Slot<T>]>,
    mask: usize,
    enqueue_pos: AtomicUsize,
    dequeue_pos: AtomicUsize,
}

unsafe impl<T: Send> Sync for MpmcQueue<T> {}
unsafe impl<T: Send> Send for MpmcQueue<T> {}

impl<T> MpmcQueue<T> {
    /// Capacity is rounded up to the next power of two (min 2).
    pub fn new(capacity: usize) -> Self {
        let cap = capacity.next_power_of_two().max(2);
        let buf: Box<[Slot<T>]> = (0..cap)
            .map(|i| Slot { seq: AtomicUsize::new(i), val: UnsafeCell::new(MaybeUninit::uninit()) })
            .collect();
        MpmcQueue { buf, mask: cap - 1, enqueue_pos: AtomicUsize::new(0), dequeue_pos: AtomicUsize::new(0) }
    }

    /// Try to enqueue; returns `Err(v)` when full.
    pub fn push(&self, v: T) -> Result<(), T> {
        let mut pos = self.enqueue_pos.load(Ordering::Relaxed);
        loop {
            let slot = &self.buf[pos & self.mask];
            let seq = slot.seq.load(Ordering::Acquire);
            let diff = seq as isize - pos as isize;
            if diff == 0 {
                match self.enqueue_pos.compare_exchange_weak(
                    pos,
                    pos + 1,
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                ) {
                    Ok(_) => {
                        unsafe { (*slot.val.get()).write(v) };
                        slot.seq.store(pos + 1, Ordering::Release);
                        return Ok(());
                    }
                    Err(p) => pos = p,
                }
            } else if diff < 0 {
                return Err(v); // full
            } else {
                pos = self.enqueue_pos.load(Ordering::Relaxed);
            }
        }
    }

    /// Try to dequeue; `None` when empty.
    pub fn pop(&self) -> Option<T> {
        let mut pos = self.dequeue_pos.load(Ordering::Relaxed);
        loop {
            let slot = &self.buf[pos & self.mask];
            let seq = slot.seq.load(Ordering::Acquire);
            let diff = seq as isize - (pos + 1) as isize;
            if diff == 0 {
                match self.dequeue_pos.compare_exchange_weak(
                    pos,
                    pos + 1,
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                ) {
                    Ok(_) => {
                        let v = unsafe { (*slot.val.get()).assume_init_read() };
                        slot.seq.store(pos + self.mask + 1, Ordering::Release);
                        return Some(v);
                    }
                    Err(p) => pos = p,
                }
            } else if diff < 0 {
                return None; // empty
            } else {
                pos = self.dequeue_pos.load(Ordering::Relaxed);
            }
        }
    }

    /// Queue capacity (after power-of-two rounding).
    pub fn capacity(&self) -> usize {
        self.mask + 1
    }

    /// Approximate number of queued items (for load-aware dispatch).
    ///
    /// Reads `dequeue_pos` *first*: `enqueue_pos` read afterwards is
    /// then always ≥ the dequeue snapshot (both counters are monotone
    /// and `d ≤ e` holds at every instant), so the subtraction can
    /// never underflow into a transient garbage length. Reading in the
    /// opposite order lets consumers advance `d` past a stale `e`
    /// snapshot, which would wrap to a huge value (or clamp a busy
    /// queue to 0). The result may transiently *over*-count items
    /// enqueued between the two reads, so it is clamped to capacity —
    /// the return value is always in `[0, capacity]`.
    pub fn len_approx(&self) -> usize {
        let d = self.dequeue_pos.load(Ordering::Acquire);
        let e = self.enqueue_pos.load(Ordering::Acquire);
        e.saturating_sub(d).min(self.capacity())
    }
}

impl<T> Drop for MpmcQueue<T> {
    fn drop(&mut self) {
        while self.pop().is_some() {}
    }
}

/// Per-worker AOT task queue (§5.2): pre-filled before the mega-kernel
/// launches, consumed in FIFO order by exactly one worker. The worker
/// may only *peek* the head and execute it once its dependent event is
/// activated — head-of-line blocking is intentional and deadlock-free
/// because tasks are enqueued in linearized (topological) order.
#[derive(Debug, Default)]
pub struct AotQueue {
    items: Vec<usize>,
    head: usize,
}

impl AotQueue {
    pub fn new(items: Vec<usize>) -> Self {
        AotQueue { items, head: 0 }
    }

    pub fn peek(&self) -> Option<usize> {
        self.items.get(self.head).copied()
    }

    pub fn advance(&mut self) {
        self.head += 1;
    }

    pub fn remaining(&self) -> usize {
        self.items.len() - self.head
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn fifo_single_thread() {
        let q = MpmcQueue::new(4);
        for i in 0..4 {
            q.push(i).unwrap();
        }
        assert!(q.push(9).is_err(), "queue should be full");
        for i in 0..4 {
            assert_eq!(q.pop(), Some(i));
        }
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn capacity_rounds_up() {
        let q = MpmcQueue::new(3);
        for i in 0..4 {
            q.push(i).unwrap();
        }
        assert!(q.push(4).is_err());
    }

    #[test]
    fn mpmc_no_loss_no_dup() {
        const PRODUCERS: usize = 4;
        const PER: usize = 5000;
        let q = Arc::new(MpmcQueue::new(PRODUCERS * PER));
        let mut handles = Vec::new();
        for p in 0..PRODUCERS {
            let q = q.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..PER {
                    q.push(p * PER + i).unwrap();
                }
            }));
        }
        let consumers: Vec<_> = (0..3)
            .map(|_| {
                let q = q.clone();
                std::thread::spawn(move || {
                    let mut got = Vec::new();
                    let mut idle = 0;
                    while idle < 200_000 {
                        match q.pop() {
                            Some(v) => {
                                got.push(v);
                                idle = 0;
                            }
                            None => {
                                idle += 1;
                                std::hint::spin_loop();
                            }
                        }
                    }
                    got
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let mut all: Vec<usize> = consumers.into_iter().flat_map(|c| c.join().unwrap()).collect();
        all.sort_unstable();
        let expect: Vec<usize> = (0..PRODUCERS * PER).collect();
        assert_eq!(all, expect);
    }

    #[test]
    fn len_approx_tracks_sequential_ops() {
        let q = MpmcQueue::new(8);
        assert_eq!(q.len_approx(), 0);
        for i in 0..8 {
            q.push(i).unwrap();
            assert_eq!(q.len_approx(), i + 1);
        }
        for i in (0..8).rev() {
            q.pop().unwrap();
            assert_eq!(q.len_approx(), i);
        }
        // wrap around the ring a few times; length stays exact when
        // quiescent.
        for round in 0..5 {
            for i in 0..3 {
                q.push(round * 10 + i).unwrap();
            }
            assert_eq!(q.len_approx(), 3);
            while q.pop().is_some() {}
            assert_eq!(q.len_approx(), 0);
        }
    }

    #[test]
    fn len_approx_bounded_under_contention() {
        // producers and consumers hammer the ring while observers
        // sample len_approx: it must never report a value outside
        // [0, capacity], in particular never a wrapped negative.
        let q = Arc::new(MpmcQueue::new(64));
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let mut handles = Vec::new();
        for p in 0..2 {
            let q = q.clone();
            let stop = stop.clone();
            handles.push(std::thread::spawn(move || {
                let mut i = 0usize;
                while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                    let _ = q.push(p * 1_000_000 + i);
                    i += 1;
                }
            }));
        }
        for _ in 0..2 {
            let q = q.clone();
            let stop = stop.clone();
            handles.push(std::thread::spawn(move || {
                while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                    let _ = q.pop();
                }
            }));
        }
        let cap = q.capacity();
        for _ in 0..200_000 {
            let l = q.len_approx();
            assert!(l <= cap, "len_approx {l} exceeds capacity {cap}");
        }
        stop.store(true, std::sync::atomic::Ordering::Relaxed);
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn aot_queue_peek_advance() {
        let mut q = AotQueue::new(vec![7, 8, 9]);
        assert_eq!(q.peek(), Some(7));
        assert_eq!(q.peek(), Some(7)); // peek is non-destructive
        q.advance();
        assert_eq!(q.peek(), Some(8));
        assert_eq!(q.remaining(), 2);
        q.advance();
        q.advance();
        assert_eq!(q.peek(), None);
    }
}
