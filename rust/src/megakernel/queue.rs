//! Fixed-capacity lock-free queues — the device-memory circular buffers
//! of §6.1 ("event queues and task queues are implemented as circular
//! buffers ... enqueue and dequeue operations rely only on low-cost
//! atomicAdd instructions").
//!
//! [`MpmcQueue`] is the classic bounded MPMC ring (per-slot sequence
//! numbers, Vyukov-style): workers push activated events to schedulers,
//! and schedulers push JIT tasks to workers, without locks on the hot
//! path. The per-worker AOT queue needs no atomics at all: it is filled
//! once before launch and consumed by a single worker ([`AotQueue`]).

use std::cell::UnsafeCell;
use std::mem::MaybeUninit;
use std::sync::atomic::{AtomicUsize, Ordering};

struct Slot<T> {
    seq: AtomicUsize,
    val: UnsafeCell<MaybeUninit<T>>,
}

/// Bounded multi-producer multi-consumer queue.
pub struct MpmcQueue<T> {
    buf: Box<[Slot<T>]>,
    mask: usize,
    enqueue_pos: AtomicUsize,
    dequeue_pos: AtomicUsize,
}

unsafe impl<T: Send> Sync for MpmcQueue<T> {}
unsafe impl<T: Send> Send for MpmcQueue<T> {}

impl<T> MpmcQueue<T> {
    /// Capacity is rounded up to the next power of two (min 2).
    pub fn new(capacity: usize) -> Self {
        let cap = capacity.next_power_of_two().max(2);
        let buf: Box<[Slot<T>]> = (0..cap)
            .map(|i| Slot { seq: AtomicUsize::new(i), val: UnsafeCell::new(MaybeUninit::uninit()) })
            .collect();
        MpmcQueue { buf, mask: cap - 1, enqueue_pos: AtomicUsize::new(0), dequeue_pos: AtomicUsize::new(0) }
    }

    /// Try to enqueue; returns `Err(v)` when full.
    pub fn push(&self, v: T) -> Result<(), T> {
        let mut pos = self.enqueue_pos.load(Ordering::Relaxed);
        loop {
            let slot = &self.buf[pos & self.mask];
            let seq = slot.seq.load(Ordering::Acquire);
            let diff = seq as isize - pos as isize;
            if diff == 0 {
                match self.enqueue_pos.compare_exchange_weak(
                    pos,
                    pos + 1,
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                ) {
                    Ok(_) => {
                        unsafe { (*slot.val.get()).write(v) };
                        slot.seq.store(pos + 1, Ordering::Release);
                        return Ok(());
                    }
                    Err(p) => pos = p,
                }
            } else if diff < 0 {
                return Err(v); // full
            } else {
                pos = self.enqueue_pos.load(Ordering::Relaxed);
            }
        }
    }

    /// Try to dequeue; `None` when empty.
    pub fn pop(&self) -> Option<T> {
        let mut pos = self.dequeue_pos.load(Ordering::Relaxed);
        loop {
            let slot = &self.buf[pos & self.mask];
            let seq = slot.seq.load(Ordering::Acquire);
            let diff = seq as isize - (pos + 1) as isize;
            if diff == 0 {
                match self.dequeue_pos.compare_exchange_weak(
                    pos,
                    pos + 1,
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                ) {
                    Ok(_) => {
                        let v = unsafe { (*slot.val.get()).assume_init_read() };
                        slot.seq.store(pos + self.mask + 1, Ordering::Release);
                        return Some(v);
                    }
                    Err(p) => pos = p,
                }
            } else if diff < 0 {
                return None; // empty
            } else {
                pos = self.dequeue_pos.load(Ordering::Relaxed);
            }
        }
    }

    /// Approximate number of queued items (for load-aware dispatch).
    pub fn len_approx(&self) -> usize {
        let e = self.enqueue_pos.load(Ordering::Relaxed);
        let d = self.dequeue_pos.load(Ordering::Relaxed);
        e.saturating_sub(d)
    }
}

impl<T> Drop for MpmcQueue<T> {
    fn drop(&mut self) {
        while self.pop().is_some() {}
    }
}

/// Per-worker AOT task queue (§5.2): pre-filled before the mega-kernel
/// launches, consumed in FIFO order by exactly one worker. The worker
/// may only *peek* the head and execute it once its dependent event is
/// activated — head-of-line blocking is intentional and deadlock-free
/// because tasks are enqueued in linearized (topological) order.
#[derive(Debug, Default)]
pub struct AotQueue {
    items: Vec<usize>,
    head: usize,
}

impl AotQueue {
    pub fn new(items: Vec<usize>) -> Self {
        AotQueue { items, head: 0 }
    }

    pub fn peek(&self) -> Option<usize> {
        self.items.get(self.head).copied()
    }

    pub fn advance(&mut self) {
        self.head += 1;
    }

    pub fn remaining(&self) -> usize {
        self.items.len() - self.head
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn fifo_single_thread() {
        let q = MpmcQueue::new(4);
        for i in 0..4 {
            q.push(i).unwrap();
        }
        assert!(q.push(9).is_err(), "queue should be full");
        for i in 0..4 {
            assert_eq!(q.pop(), Some(i));
        }
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn capacity_rounds_up() {
        let q = MpmcQueue::new(3);
        for i in 0..4 {
            q.push(i).unwrap();
        }
        assert!(q.push(4).is_err());
    }

    #[test]
    fn mpmc_no_loss_no_dup() {
        const PRODUCERS: usize = 4;
        const PER: usize = 5000;
        let q = Arc::new(MpmcQueue::new(PRODUCERS * PER));
        let mut handles = Vec::new();
        for p in 0..PRODUCERS {
            let q = q.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..PER {
                    q.push(p * PER + i).unwrap();
                }
            }));
        }
        let consumers: Vec<_> = (0..3)
            .map(|_| {
                let q = q.clone();
                std::thread::spawn(move || {
                    let mut got = Vec::new();
                    let mut idle = 0;
                    while idle < 200_000 {
                        match q.pop() {
                            Some(v) => {
                                got.push(v);
                                idle = 0;
                            }
                            None => {
                                idle += 1;
                                std::hint::spin_loop();
                            }
                        }
                    }
                    got
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let mut all: Vec<usize> = consumers.into_iter().flat_map(|c| c.join().unwrap()).collect();
        all.sort_unstable();
        let expect: Vec<usize> = (0..PRODUCERS * PER).collect();
        assert_eq!(all, expect);
    }

    #[test]
    fn aot_queue_peek_advance() {
        let mut q = AotQueue::new(vec![7, 8, 9]);
        assert_eq!(q.peek(), Some(7));
        assert_eq!(q.peek(), Some(7)); // peek is non-destructive
        q.advance();
        assert_eq!(q.peek(), Some(8));
        assert_eq!(q.remaining(), 2);
        q.advance();
        q.advance();
        assert_eq!(q.peek(), None);
    }
}
