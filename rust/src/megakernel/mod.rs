//! The in-kernel parallel runtime (§5): workers, schedulers, events,
//! hybrid JIT/AOT launch, paged shared memory.
pub mod event;
pub mod queue;
pub mod runtime;
pub mod smem;

pub use event::EventTable;
pub use queue::{AotQueue, MpmcQueue};
pub use runtime::{KernelError, MegaConfig, MegaKernel, PersistentMegaKernel, RunReport, TaskExecutor};
pub use smem::{task_smem_bytes, PagedSmem, SmemError, PAGE_BYTES};
