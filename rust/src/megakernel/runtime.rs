//! The in-kernel parallel runtime (§5), threaded — with persistent
//! worker threads.
//!
//! One OS thread stands in for each SM. Workers own a JIT task queue
//! (filled by schedulers) and an AOT queue (pre-filled in linearized
//! order, §5.2); schedulers own event queues. A worker finishing a task
//! notifies the task's triggering event with one atomic add; the
//! notification that crosses the activation threshold hands the event to
//! a scheduler (when it launches JIT tasks) — AOT tasks instead wait on
//! their queue head for [`EventTable::activated`]. The designated end
//! event raises the per-iteration stop flag, ending the epoch.
//!
//! Two front-ends share one scheduling substrate ([`KernelState`]):
//!
//! * [`PersistentMegaKernel`] is the paper-faithful model and the
//!   serving hot path. The GPU megakernel is launched **once** and its
//!   thread blocks then loop in-kernel over decode iterations; here,
//!   worker and scheduler threads are spawned once at construction and
//!   parked between iterations. `run()` is the analogue of the paper's
//!   in-kernel re-processing of the start event: re-arm the event table
//!   and queues under a fresh epoch (generation counter), publish the
//!   executor, wake the parked threads, and wait for the end event —
//!   no thread spawn or join on the hot path. Threads are only torn
//!   down on `Drop`.
//! * [`MegaKernel`] is the legacy scoped variant: every `run()` spawns
//!   and joins the full thread complement via `std::thread::scope`. It
//!   is kept as the measured "kernel-launch-per-iteration" baseline
//!   (see `benches/launch_overhead.rs`) and for borrowed-graph
//!   one-shot validation paths.
//!
//! Epoch protocol (persistent): `run()` may only re-arm while every
//! thread is parked, which is guaranteed by a quiesce barrier — a run
//! returns only after all workers and schedulers have finished the
//! epoch and checked back in. That barrier is also what makes it sound
//! to hand the borrowed [`TaskExecutor`] to the persistent threads for
//! the duration of a single epoch.
//!
//! Differences from the CUDA implementation, by necessity of substrate:
//! threads instead of SMs, `std::hint::spin_loop`+`yield_now` instead
//! of `nanosleep`-free device spinning, and condvar parking instead of
//! the device-side wait on the start event's semaphore.

use crate::megakernel::event::EventTable;
use crate::megakernel::queue::{AotQueue, MpmcQueue};
use crate::metrics::{MetricsSnapshot, RuntimeMetrics};
use crate::ops::LaunchMode;
use crate::tgraph::{CompiledGraph, TaskDesc, TaskId};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

crate::util::boundary_error! {
    /// Typed failure from a mega-kernel epoch — the `megakernel`
    /// boundary error for [`MegaKernel::run`] /
    /// [`PersistentMegaKernel::run`] (watchdog timeout, executor panic,
    /// a queue wedged at arming). Legacy `String` contexts convert
    /// through the `From<KernelError> for String` shim; the serving
    /// layer converts it into its own typed error.
    KernelError
}

/// Runtime shape: how many SM threads play worker vs scheduler (Table 1).
#[derive(Clone, Copy, Debug)]
pub struct MegaConfig {
    pub workers: usize,
    pub schedulers: usize,
    /// Wall-clock safety net: `run` aborts (returning an error) if the
    /// graph has not drained in this long — surfaces scheduling bugs as
    /// test failures instead of hangs.
    pub timeout: Duration,
}

impl Default for MegaConfig {
    fn default() -> Self {
        // CPU-scale default: a few workers, one scheduler warp-group.
        MegaConfig { workers: 4, schedulers: 1, timeout: Duration::from_secs(60) }
    }
}

impl MegaConfig {
    /// Reject shapes the runtime cannot run: at least one worker and
    /// one scheduler, and a nonzero watchdog timeout (a zero timeout
    /// would abort every epoch before the end event can fire). The
    /// watchdog bounds a *single epoch*; per-request deadlines are a
    /// serving-layer concern, enforced between epochs by the server
    /// front-end as scheduled terminations.
    pub fn validate(&self) -> Result<(), String> {
        if self.workers == 0 || self.schedulers == 0 {
            return Err(format!(
                "mega-kernel needs >= 1 worker and >= 1 scheduler (got {} / {})",
                self.workers, self.schedulers
            ));
        }
        if self.timeout.is_zero() {
            return Err("mega-kernel watchdog timeout must be > 0".into());
        }
        Ok(())
    }
}

/// Anything that can execute task bodies. The scheduling runtime is
/// generic over this: a no-op executor measures pure runtime overhead,
/// `exec::TileExecutor` runs real numerics through PJRT.
pub trait TaskExecutor: Sync {
    fn execute(&self, task: &TaskDesc);
}

impl<F: Fn(&TaskDesc) + Sync> TaskExecutor for F {
    fn execute(&self, task: &TaskDesc) {
        self(task)
    }
}

/// Outcome of one mega-kernel invocation.
#[derive(Clone, Debug)]
pub struct RunReport {
    pub elapsed: Duration,
    pub metrics: MetricsSnapshot,
    /// Tasks executed per worker (load-balance diagnostics).
    pub per_worker_tasks: Vec<u64>,
    /// Epoch (generation) number of this iteration — 1-based, counted
    /// across the kernel's lifetime.
    pub epoch: u64,
}

/// The scheduling substrate shared by both kernel front-ends: event
/// table, queues, metrics, and the per-epoch arming logic. Holds no
/// reference to the graph — callers pass it in, so the same state works
/// for both the borrowed (`MegaKernel<'g>`) and owned
/// (`PersistentMegaKernel`) graph flavors.
struct KernelState {
    cfg: MegaConfig,
    events: EventTable,
    /// Worker JIT queues (schedulers → worker).
    jit_queues: Vec<MpmcQueue<TaskId>>,
    /// Scheduler event queues (workers → scheduler).
    event_queues: Vec<MpmcQueue<usize>>,
    /// Round-robin cursor for JIT dispatch.
    dispatch_cursor: AtomicUsize,
    /// Per-iteration stop flag: raised by the end event or the
    /// watchdog, cleared when the next epoch is armed.
    iter_stop: AtomicBool,
    metrics: RuntimeMetrics,
    /// AOT assignment per worker, rebuilt per epoch (interior
    /// mutability so arming through `&self` can refill each queue).
    aot_assignment: Vec<Mutex<AotQueue>>,
    /// Tasks executed per worker this epoch.
    per_worker_tasks: Vec<AtomicUsize>,
    /// Generation counter: bumped once per armed epoch.
    epoch: AtomicU64,
}

impl KernelState {
    fn new(graph: &CompiledGraph, cfg: MegaConfig) -> Self {
        assert!(cfg.workers >= 1 && cfg.schedulers >= 1);
        let nev = graph.tgraph.events.len();
        let required: Vec<usize> = (0..nev).map(|e| graph.linear.required[e]).collect();
        let ntasks = graph.tgraph.tasks.len();
        KernelState {
            cfg,
            events: EventTable::new(&required),
            jit_queues: (0..cfg.workers).map(|_| MpmcQueue::new(ntasks + 2)).collect(),
            event_queues: (0..cfg.schedulers).map(|_| MpmcQueue::new(nev + 2)).collect(),
            dispatch_cursor: AtomicUsize::new(0),
            iter_stop: AtomicBool::new(false),
            metrics: RuntimeMetrics::default(),
            aot_assignment: (0..cfg.workers).map(|_| Mutex::new(AotQueue::default())).collect(),
            per_worker_tasks: (0..cfg.workers).map(|_| AtomicUsize::new(0)).collect(),
            epoch: AtomicU64::new(0),
        }
    }

    /// Re-arm the substrate for a new iteration and seed the start
    /// event. Returns the new epoch number.
    ///
    /// Caller must guarantee no worker or scheduler thread is inside an
    /// epoch (threads parked, or not yet spawned) — the quiesce barrier
    /// of both front-ends establishes this.
    fn arm(&self, graph: &CompiledGraph) -> Result<u64, String> {
        self.events.reset();
        self.metrics.reset();
        for c in &self.per_worker_tasks {
            c.store(0, Ordering::Relaxed);
        }
        // A timed-out epoch can leave stale items behind; drain so they
        // cannot leak into this iteration.
        for q in &self.jit_queues {
            while q.pop().is_some() {}
        }
        for q in &self.event_queues {
            while q.pop().is_some() {}
        }
        self.iter_stop.store(false, Ordering::Release);
        self.pre_enqueue_aot(graph);
        // seed: the start event is born-activated; hand it to scheduler 0
        // so JIT successors launch, AOT successors see `activated()`.
        let start = graph.tgraph.start_event;
        self.event_queues[0].push(start).map_err(|_| "event queue full at seed".to_string())?;
        Ok(self.epoch.fetch_add(1, Ordering::Relaxed) + 1)
    }

    /// Pre-enqueue all AOT tasks round-robin across workers in
    /// linearized order (§5.2 "All AOT tasks are pre-enqueued").
    fn pre_enqueue_aot(&self, graph: &CompiledGraph) {
        let tasks = &graph.tgraph.tasks;
        let mut per_worker: Vec<Vec<TaskId>> = vec![Vec::new(); self.cfg.workers];
        let mut cursor = 0usize;
        for &tid in &graph.linear.order {
            if tasks[tid].launch == LaunchMode::Aot {
                per_worker[cursor % self.cfg.workers].push(tid);
                cursor += 1;
            }
        }
        for (w, items) in per_worker.into_iter().enumerate() {
            // poison recovery is safe: the queue is rebuilt from scratch
            // every epoch (a panicking executor may have poisoned it).
            *self.aot_assignment[w].lock().unwrap_or_else(|p| p.into_inner()) = AotQueue::new(items);
        }
    }

    /// Build the report for a finished epoch, or the timeout error if
    /// the end event never activated.
    fn report(&self, graph: &CompiledGraph, elapsed: Duration, epoch: u64) -> Result<RunReport, String> {
        if !self.events.activated(graph.tgraph.end_event) {
            return Err(format!(
                "mega-kernel timed out after {elapsed:?}: end event not activated"
            ));
        }
        Ok(RunReport {
            elapsed,
            metrics: self.metrics.snapshot(),
            per_worker_tasks: self
                .per_worker_tasks
                .iter()
                .map(|c| c.load(Ordering::Relaxed) as u64)
                .collect(),
            epoch,
        })
    }

    /// One worker's share of one epoch: drain JIT + AOT work until the
    /// per-iteration stop flag rises.
    fn worker_epoch<E: TaskExecutor + ?Sized>(
        &self,
        graph: &CompiledGraph,
        w: usize,
        exec: &E,
        deadline: Instant,
    ) {
        let tasks = &graph.tgraph.tasks;
        let mut aot = self.aot_assignment[w].lock().unwrap_or_else(|p| p.into_inner());
        let count = &self.per_worker_tasks[w];
        let mut idle: u32 = 0;
        loop {
            if self.iter_stop.load(Ordering::Acquire) {
                break;
            }
            // 1. JIT queue has priority: those tasks are ready now.
            if let Some(tid) = self.jit_queues[w].pop() {
                self.run_task(graph, &tasks[tid], exec);
                count.fetch_add(1, Ordering::Relaxed);
                idle = 0;
                continue;
            }
            // 2. AOT head, if its dependent event is activated.
            if let Some(tid) = aot.peek() {
                let dep = tasks[tid].dependent_events[0];
                if self.events.activated(dep) {
                    aot.advance();
                    self.metrics.inc(&self.metrics.aot_hits);
                    self.run_task(graph, &tasks[tid], exec);
                    count.fetch_add(1, Ordering::Relaxed);
                    idle = 0;
                    continue;
                }
            }
            // 3. idle: spin briefly, then yield; check the watchdog.
            self.metrics.inc(&self.metrics.worker_idle_spins);
            idle += 1;
            if idle % 64 == 0 {
                std::thread::yield_now();
                if Instant::now() > deadline {
                    self.iter_stop.store(true, Ordering::Release);
                    break;
                }
            } else {
                std::hint::spin_loop();
            }
        }
    }

    /// One scheduler's share of one epoch: pop activated events and
    /// dispatch their JIT successors.
    fn scheduler_epoch(&self, graph: &CompiledGraph, sc: usize, deadline: Instant) {
        let tgraph = &graph.tgraph;
        let linear = &graph.linear;
        let mut idle: u32 = 0;
        loop {
            if self.iter_stop.load(Ordering::Acquire) {
                break;
            }
            match self.event_queues[sc].pop() {
                Some(ev) => {
                    idle = 0;
                    let t0 = Instant::now();
                    // dispatch the event's JIT successors; range encoding
                    // from linearization gives them contiguously.
                    if let Some((first, last)) = linear.event_range[ev] {
                        for pos in first..=last {
                            let tid = linear.order[pos];
                            if tgraph.tasks[tid].launch == LaunchMode::Jit {
                                self.dispatch_jit(tid);
                            }
                        }
                    }
                    self.metrics
                        .sched_ns
                        .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                }
                None => {
                    self.metrics.inc(&self.metrics.sched_idle_spins);
                    idle += 1;
                    if idle % 64 == 0 {
                        std::thread::yield_now();
                        if Instant::now() > deadline {
                            self.iter_stop.store(true, Ordering::Release);
                            break;
                        }
                    } else {
                        std::hint::spin_loop();
                    }
                }
            }
        }
    }

    /// Round-robin JIT dispatch with a shortest-queue refinement over a
    /// small probe window (decentralized, local state only — §6.1).
    fn dispatch_jit(&self, tid: TaskId) {
        self.metrics.inc(&self.metrics.jit_dispatches);
        let n = self.cfg.workers;
        let base = self.dispatch_cursor.fetch_add(1, Ordering::Relaxed);
        let mut best = base % n;
        let mut best_len = self.jit_queues[best].len_approx();
        for probe in 1..3.min(n) {
            let cand = (base + probe) % n;
            let l = self.jit_queues[cand].len_approx();
            if l < best_len {
                best = cand;
                best_len = l;
            }
        }
        let mut target = best;
        while self.jit_queues[target].push(tid).is_err() {
            // queue sized to total task count: full should be impossible,
            // but fall over to the next worker defensively.
            target = (target + 1) % n;
        }
    }

    fn run_task<E: TaskExecutor + ?Sized>(&self, graph: &CompiledGraph, task: &TaskDesc, exec: &E) {
        let t0 = Instant::now();
        if task.kind.is_dummy() {
            self.metrics.inc(&self.metrics.dummy_tasks);
        } else {
            exec.execute(task);
        }
        self.metrics.inc(&self.metrics.tasks_executed);
        self.metrics.task_ns.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        // notify the triggering event (exactly one — graph is normalized).
        if let Some(&ev) = task.trigger_events.first() {
            if self.events.notify(ev) {
                self.on_activation(graph, ev);
            }
        }
    }

    fn on_activation(&self, graph: &CompiledGraph, ev: usize) {
        self.metrics.inc(&self.metrics.events_activated);
        if ev == graph.tgraph.end_event {
            self.iter_stop.store(true, Ordering::Release);
            return;
        }
        // hand to a scheduler only if the event launches JIT tasks; pure
        // AOT successors are found by their workers via `activated()`.
        let linear = &graph.linear;
        let has_jit = linear.event_range[ev]
            .map(|(f, l)| {
                (f..=l).any(|p| graph.tgraph.tasks[linear.order[p]].launch == LaunchMode::Jit)
            })
            .unwrap_or(false);
        if has_jit {
            let sc = ev % self.cfg.schedulers;
            let mut target = sc;
            while self.event_queues[target].push(ev).is_err() {
                target = (target + 1) % self.cfg.schedulers;
            }
        }
    }
}

/// The scoped mega-kernel over one borrowed compiled tGraph: every
/// `run()` spawns and joins the full worker/scheduler complement.
///
/// This models the kernel-launch-per-iteration world the paper argues
/// against; [`PersistentMegaKernel`] is the persistent counterpart used
/// on the serving hot path. Kept for one-shot validation and as the
/// baseline in `benches/launch_overhead.rs`.
pub struct MegaKernel<'g> {
    graph: &'g CompiledGraph,
    state: KernelState,
}

impl<'g> MegaKernel<'g> {
    pub fn new(graph: &'g CompiledGraph, cfg: MegaConfig) -> Self {
        MegaKernel { graph, state: KernelState::new(graph, cfg) }
    }

    /// Execute the whole tGraph once. Returns a report, or a
    /// [`KernelError`] on timeout (stuck dependency — indicates a
    /// compiler bug).
    pub fn run<E: TaskExecutor>(&self, exec: &E) -> Result<RunReport, KernelError> {
        let epoch = self.state.arm(self.graph).map_err(KernelError)?;
        let t0 = Instant::now();
        let deadline = t0 + self.state.cfg.timeout;
        std::thread::scope(|s| {
            for w in 0..self.state.cfg.workers {
                s.spawn(move || self.state.worker_epoch(self.graph, w, exec, deadline));
            }
            for sc in 0..self.state.cfg.schedulers {
                s.spawn(move || self.state.scheduler_epoch(self.graph, sc, deadline));
            }
        });
        self.state.report(self.graph, t0.elapsed(), epoch).map_err(KernelError)
    }
}

/// Which role a persistent thread plays.
#[derive(Clone, Copy)]
enum Role {
    Worker(usize),
    Scheduler(usize),
}

/// Handshake state between `run()` and the parked threads.
struct Phase {
    /// Epoch the threads have been told to run (0 = nothing armed yet).
    armed_epoch: u64,
    /// Threads that have finished the armed epoch and are parking.
    quiesced: usize,
    /// Lifetime-erased borrow of this epoch's executor. Only valid
    /// between arming and the quiesce barrier; cleared by `run()`
    /// before it returns (see the safety comment in `run`).
    exec: Option<&'static dyn TaskExecutor>,
    deadline: Instant,
    /// An executor panicked during the armed epoch (caught so the
    /// thread still reaches the quiesce barrier instead of deadlocking
    /// `run()`); surfaced as an error from `run()`.
    panicked: bool,
    shutdown: bool,
}

struct Lifecycle {
    phase: Mutex<Phase>,
    /// Signals a newly armed epoch (or shutdown) to parked threads.
    arm: Condvar,
    /// Signals epoch completion (all threads quiesced) to `run()`.
    done: Condvar,
}

struct PersistentInner {
    graph: Arc<CompiledGraph>,
    state: KernelState,
    lifecycle: Lifecycle,
}

impl PersistentInner {
    fn thread_total(&self) -> usize {
        self.state.cfg.workers + self.state.cfg.schedulers
    }
}

/// The persistent mega-kernel: worker and scheduler threads are spawned
/// once here, parked between iterations, re-armed per `run()` via an
/// epoch counter, and only torn down on `Drop` — the threaded analogue
/// of launching the megakernel once and looping in-kernel (§5–6).
pub struct PersistentMegaKernel {
    inner: Arc<PersistentInner>,
    threads: Vec<std::thread::JoinHandle<()>>,
    /// Unique prefix of this kernel's thread names (`<prefix>-worker-N`
    /// / `<prefix>-sched-N`), for leak diagnostics via /proc.
    thread_prefix: String,
}

/// Monotone id so each kernel's resident threads are distinguishable in
/// /proc and debuggers.
static KERNEL_SEQ: AtomicUsize = AtomicUsize::new(0);

impl PersistentMegaKernel {
    /// Spawn the full worker/scheduler complement, parked until the
    /// first `run()`.
    pub fn new(graph: Arc<CompiledGraph>, cfg: MegaConfig) -> Self {
        let state = KernelState::new(&graph, cfg);
        let inner = Arc::new(PersistentInner {
            graph,
            state,
            lifecycle: Lifecycle {
                phase: Mutex::new(Phase {
                    armed_epoch: 0,
                    quiesced: 0,
                    exec: None,
                    deadline: Instant::now(),
                    panicked: false,
                    shutdown: false,
                }),
                arm: Condvar::new(),
                done: Condvar::new(),
            },
        });
        let thread_prefix = format!("mpk{}", KERNEL_SEQ.fetch_add(1, Ordering::Relaxed));
        let mut threads = Vec::with_capacity(cfg.workers + cfg.schedulers);
        for w in 0..cfg.workers {
            let inner = inner.clone();
            threads.push(
                std::thread::Builder::new()
                    .name(format!("{thread_prefix}-worker-{w}"))
                    .spawn(move || persistent_thread(inner, Role::Worker(w)))
                    .expect("spawn persistent worker"),
            );
        }
        for sc in 0..cfg.schedulers {
            let inner = inner.clone();
            threads.push(
                std::thread::Builder::new()
                    .name(format!("{thread_prefix}-sched-{sc}"))
                    .spawn(move || persistent_thread(inner, Role::Scheduler(sc)))
                    .expect("spawn persistent scheduler"),
            );
        }
        PersistentMegaKernel { inner, threads, thread_prefix }
    }

    /// Execute the whole tGraph once on the resident threads: re-arm,
    /// publish the epoch, wake, wait for the end event. No thread is
    /// spawned or joined here.
    ///
    /// Takes `&mut self` deliberately: exclusive access is what makes
    /// the lifetime erasure below sound (no second `run` can re-arm
    /// while this epoch's executor borrow is published).
    pub fn run<E: TaskExecutor>(&mut self, exec: &E) -> Result<RunReport, KernelError> {
        let inner = &self.inner;
        // Threads are parked here: either never armed, or quiesced at
        // the end of the previous run (we do not return mid-epoch).
        let epoch = inner.state.arm(&inner.graph).map_err(KernelError)?;
        let t0 = Instant::now();
        let deadline = t0 + inner.state.cfg.timeout;
        // SAFETY: the erased borrow is published for the duration of
        // this call only. `run` does not return until every worker and
        // scheduler has passed the quiesce barrier below, after which
        // the slot is cleared — no thread can hold or dereference the
        // borrow once `run` returns, so `exec` outlives every use.
        // `&mut self` excludes a concurrent re-arm publishing a second
        // borrow while this one is live.
        let erased: &'static dyn TaskExecutor =
            unsafe { &*(exec as &dyn TaskExecutor as *const dyn TaskExecutor) };
        {
            let mut ph = inner.lifecycle.phase.lock().unwrap();
            ph.armed_epoch = epoch;
            ph.quiesced = 0;
            ph.exec = Some(erased);
            ph.deadline = deadline;
            ph.panicked = false;
            inner.lifecycle.arm.notify_all();
        }
        // Wait for the epoch to drain — the host-side analogue of the
        // paper's wait on the end event.
        let total = inner.thread_total();
        let mut ph = inner.lifecycle.phase.lock().unwrap();
        while ph.quiesced < total {
            let (guard, _) = inner
                .lifecycle
                .done
                .wait_timeout(ph, Duration::from_millis(50))
                .unwrap();
            ph = guard;
            // Belt-and-braces watchdog: workers check the deadline only
            // while idle, so force the stop flag from here too once it
            // has passed.
            if Instant::now() > deadline {
                inner.state.iter_stop.store(true, Ordering::Release);
            }
        }
        ph.exec = None;
        let panicked = ph.panicked;
        drop(ph);
        if panicked {
            return Err(KernelError(format!("task executor panicked during epoch {epoch}")));
        }
        inner.state.report(&inner.graph, t0.elapsed(), epoch).map_err(KernelError)
    }

    pub fn graph(&self) -> &CompiledGraph {
        &self.inner.graph
    }

    /// Prefix of this kernel's resident thread names (leak diagnostics).
    pub fn thread_name_prefix(&self) -> &str {
        &self.thread_prefix
    }

    pub fn config(&self) -> MegaConfig {
        self.inner.state.cfg
    }

    /// Epochs (iterations) run so far over this kernel's lifetime.
    pub fn epochs(&self) -> u64 {
        self.inner.state.epoch.load(Ordering::Relaxed)
    }

    /// Resident thread count (workers + schedulers).
    pub fn thread_count(&self) -> usize {
        self.threads.len()
    }
}

impl Drop for PersistentMegaKernel {
    fn drop(&mut self) {
        {
            let mut ph = self
                .inner
                .lifecycle
                .phase
                .lock()
                .unwrap_or_else(|poisoned| poisoned.into_inner());
            ph.shutdown = true;
            self.inner.lifecycle.arm.notify_all();
        }
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

/// Body of one persistent thread: park → run epoch → quiesce → repeat,
/// until shutdown.
fn persistent_thread(inner: Arc<PersistentInner>, role: Role) {
    let mut seen_epoch = 0u64;
    loop {
        {
            // Park until a new epoch is armed (or shutdown). The erased
            // executor borrow is confined to this block so it cannot
            // outlive the epoch it belongs to.
            let (exec, deadline) = {
                let mut ph = inner.lifecycle.phase.lock().unwrap();
                loop {
                    if ph.shutdown {
                        return;
                    }
                    if ph.armed_epoch != seen_epoch {
                        break;
                    }
                    ph = inner.lifecycle.arm.wait(ph).unwrap();
                }
                seen_epoch = ph.armed_epoch;
                (ph.exec, ph.deadline)
            };
            if let Some(exec) = exec {
                // Catch executor panics: a dead thread would otherwise
                // leave the quiesce barrier short forever, deadlocking
                // `run()`. The panic is surfaced as a `run()` error.
                let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    match role {
                        Role::Worker(w) => {
                            inner.state.worker_epoch(&inner.graph, w, exec, deadline)
                        }
                        Role::Scheduler(sc) => {
                            inner.state.scheduler_epoch(&inner.graph, sc, deadline)
                        }
                    }
                }));
                if outcome.is_err() {
                    // free peers still spinning on this epoch, then
                    // record the failure for `run()`.
                    inner.state.iter_stop.store(true, Ordering::Release);
                    inner
                        .lifecycle
                        .phase
                        .lock()
                        .unwrap_or_else(|poisoned| poisoned.into_inner())
                        .panicked = true;
                }
            }
        }
        // Quiesce barrier: the last thread out releases `run()`.
        let mut ph = inner
            .lifecycle
            .phase
            .lock()
            .unwrap_or_else(|poisoned| poisoned.into_inner());
        ph.quiesced += 1;
        if ph.quiesced == inner.thread_total() {
            inner.lifecycle.done.notify_all();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{build_decode_graph, GraphOptions, ModelConfig};
    use crate::tgraph::{compile, CompileOptions, DecomposeConfig};
    use std::collections::HashSet;
    use std::sync::Mutex as StdMutex;

    fn compiled_tiny(batch: usize) -> CompiledGraph {
        let cfg = ModelConfig::tiny();
        let g = build_decode_graph(&cfg, &GraphOptions { batch, kv_len: 16, ..Default::default() });
        compile(
            &g,
            &CompileOptions {
                decompose: DecomposeConfig { target_tasks: 8, min_tile_cols: 8 },
                ..Default::default()
            },
        )
    }

    #[test]
    fn executes_every_task_exactly_once() {
        let c = compiled_tiny(2);
        let mk = MegaKernel::new(&c, MegaConfig { workers: 4, schedulers: 2, ..Default::default() });
        let seen = StdMutex::new(Vec::new());
        let report = mk.run(&|t: &TaskDesc| seen.lock().unwrap().push(t.id)).unwrap();
        let seen = seen.lock().unwrap();
        let uniq: HashSet<_> = seen.iter().copied().collect();
        assert_eq!(uniq.len(), seen.len(), "a task ran twice");
        // every non-dummy task ran (dummies are skipped by the executor
        // wrapper but still counted in metrics).
        let expected = c.tgraph.real_task_count();
        assert_eq!(seen.len(), expected);
        assert_eq!(
            report.metrics.tasks_executed as usize,
            c.tgraph.tasks.len(),
            "dummy + real tasks all pass through the runtime"
        );
    }

    #[test]
    fn respects_topological_order() {
        let c = compiled_tiny(1);
        let mk = MegaKernel::new(&c, MegaConfig { workers: 3, schedulers: 1, ..Default::default() });
        // record completion order positions; a consumer must complete
        // after every producer its dependent event waits on.
        let order = StdMutex::new(Vec::new());
        mk.run(&|t: &TaskDesc| order.lock().unwrap().push(t.id)).unwrap();
        let order = order.lock().unwrap();
        let pos: std::collections::HashMap<usize, usize> =
            order.iter().enumerate().map(|(i, &t)| (t, i)).collect();
        for t in &c.tgraph.tasks {
            if t.kind.is_dummy() {
                continue;
            }
            let dep = t.dependent_events[0];
            for &p in &c.tgraph.events[dep].in_tasks {
                if c.tgraph.tasks[p].kind.is_dummy() {
                    continue;
                }
                assert!(
                    pos[&p] < pos[&t.id],
                    "task {} ran before its producer {}",
                    t.id,
                    p
                );
            }
        }
    }

    #[test]
    fn single_worker_single_scheduler_works() {
        let c = compiled_tiny(1);
        let mk = MegaKernel::new(&c, MegaConfig { workers: 1, schedulers: 1, ..Default::default() });
        let n = StdMutex::new(0usize);
        mk.run(&|_: &TaskDesc| *n.lock().unwrap() += 1).unwrap();
        assert_eq!(*n.lock().unwrap(), c.tgraph.real_task_count());
    }

    #[test]
    fn rerun_reuses_kernel() {
        let c = compiled_tiny(2);
        let mk = MegaKernel::new(&c, MegaConfig::default());
        for i in 0..3 {
            let r = mk.run(&|_: &TaskDesc| {}).unwrap();
            assert_eq!(r.metrics.tasks_executed as usize, c.tgraph.tasks.len());
            assert_eq!(r.epoch, i + 1);
        }
    }

    #[test]
    fn jit_and_aot_paths_both_used() {
        let c = compiled_tiny(4);
        let mk = MegaKernel::new(&c, MegaConfig { workers: 4, schedulers: 1, ..Default::default() });
        let r = mk.run(&|_: &TaskDesc| {}).unwrap();
        assert!(r.metrics.jit_dispatches > 0, "no JIT dispatches");
        assert!(r.metrics.aot_hits > 0, "no AOT hits");
    }

    #[test]
    fn load_reasonably_balanced() {
        let c = compiled_tiny(4);
        let mk = MegaKernel::new(&c, MegaConfig { workers: 4, schedulers: 1, ..Default::default() });
        // simulate non-trivial work so balancing matters.
        let r = mk
            .run(&|_: &TaskDesc| {
                std::hint::black_box((0..500).sum::<u64>());
            })
            .unwrap();
        let total: u64 = r.per_worker_tasks.iter().sum();
        assert_eq!(total as usize, c.tgraph.tasks.len());
        for (w, &n) in r.per_worker_tasks.iter().enumerate() {
            assert!(n > 0, "worker {w} starved entirely");
        }
    }

    #[test]
    fn persistent_executes_every_task_exactly_once() {
        let c = Arc::new(compiled_tiny(2));
        let mut mk = PersistentMegaKernel::new(
            c.clone(),
            MegaConfig { workers: 4, schedulers: 2, ..Default::default() },
        );
        let seen = StdMutex::new(Vec::new());
        let report = mk.run(&|t: &TaskDesc| seen.lock().unwrap().push(t.id)).unwrap();
        let seen = seen.lock().unwrap();
        let uniq: HashSet<_> = seen.iter().copied().collect();
        assert_eq!(uniq.len(), seen.len(), "a task ran twice");
        assert_eq!(seen.len(), c.tgraph.real_task_count());
        assert_eq!(report.metrics.tasks_executed as usize, c.tgraph.tasks.len());
        assert_eq!(report.epoch, 1);
    }

    #[test]
    fn persistent_rearms_across_epochs() {
        let c = Arc::new(compiled_tiny(4));
        let mut mk = PersistentMegaKernel::new(
            c.clone(),
            MegaConfig { workers: 4, schedulers: 1, ..Default::default() },
        );
        let threads = mk.thread_count();
        for i in 0..10 {
            let r = mk.run(&|_: &TaskDesc| {}).unwrap();
            assert_eq!(r.metrics.tasks_executed as usize, c.tgraph.tasks.len());
            assert_eq!(r.epoch, i + 1);
            assert_eq!(mk.thread_count(), threads, "thread complement changed");
        }
        assert_eq!(mk.epochs(), 10);
    }

    /// First task that actually reaches the executor (dummies don't).
    fn first_real_task(c: &CompiledGraph) -> usize {
        *c.linear
            .order
            .iter()
            .find(|&&t| !c.tgraph.tasks[t].kind.is_dummy())
            .expect("graph has a real task")
    }

    #[test]
    fn persistent_recovers_after_timeout_epoch() {
        let c = Arc::new(compiled_tiny(1));
        let victim = first_real_task(&c);
        let mut mk = PersistentMegaKernel::new(
            c.clone(),
            MegaConfig {
                workers: 2,
                schedulers: 1,
                timeout: Duration::from_millis(100),
            },
        );
        // epoch 1: one task overruns the watchdog → error, not hang.
        let res = mk.run(&move |t: &TaskDesc| {
            if t.id == victim {
                std::thread::sleep(Duration::from_millis(400));
            }
        });
        assert!(res.is_err(), "watchdog should have fired");
        assert!(res.unwrap_err().0.contains("timed out"));
        // epoch 2: same kernel re-arms cleanly and completes.
        let r = mk.run(&|_: &TaskDesc| {}).unwrap();
        assert_eq!(r.metrics.tasks_executed as usize, c.tgraph.tasks.len());
    }

    #[test]
    fn persistent_survives_executor_panic() {
        let c = Arc::new(compiled_tiny(1));
        let victim = first_real_task(&c);
        let mut mk = PersistentMegaKernel::new(
            c.clone(),
            MegaConfig { workers: 2, schedulers: 1, ..Default::default() },
        );
        // epoch 1: executor panics → surfaced as an error, threads and
        // queues stay usable (no quiesce-barrier deadlock).
        let res = mk.run(&move |t: &TaskDesc| {
            if t.id == victim {
                panic!("injected executor panic");
            }
        });
        assert!(res.is_err(), "panic should surface as an error");
        assert!(res.unwrap_err().0.contains("panicked"));
        // epoch 2: same kernel re-arms cleanly and completes.
        let r = mk.run(&|_: &TaskDesc| {}).unwrap();
        assert_eq!(r.metrics.tasks_executed as usize, c.tgraph.tasks.len());
    }

    #[test]
    fn persistent_drop_joins_threads() {
        let c = Arc::new(compiled_tiny(1));
        let mut mk = PersistentMegaKernel::new(c, MegaConfig::default());
        mk.run(&|_: &TaskDesc| {}).unwrap();
        drop(mk); // must not hang or leak (asserted via /proc in prop_runtime)
    }
}
