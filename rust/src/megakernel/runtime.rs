//! The in-kernel parallel runtime (§5), threaded.
//!
//! One OS thread stands in for each SM. Workers own a JIT task queue
//! (filled by schedulers) and an AOT queue (pre-filled in linearized
//! order, §5.2); schedulers own event queues. A worker finishing a task
//! notifies the task's triggering event with one atomic add; the
//! notification that crosses the activation threshold hands the event to
//! a scheduler (when it launches JIT tasks) — AOT tasks instead wait on
//! their queue head for [`EventTable::activated`]. The designated end
//! event raises the stop flag, terminating the "kernel".
//!
//! Differences from the CUDA implementation, by necessity of substrate:
//! threads instead of SMs, `std::hint::spin_loop`+`yield_now` instead of
//! `nanosleep`-free device spinning, and one `run()` per decode
//! iteration (the GPU kernel instead re-processes the start event
//! in-kernel; the serving engine owns that loop here — see
//! `serving::engine`).

use crate::megakernel::event::EventTable;
use crate::megakernel::queue::{AotQueue, MpmcQueue};
use crate::metrics::{MetricsSnapshot, RuntimeMetrics};
use crate::ops::LaunchMode;
use crate::tgraph::{CompiledGraph, TaskDesc, TaskId};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Runtime shape: how many SM threads play worker vs scheduler (Table 1).
#[derive(Clone, Copy, Debug)]
pub struct MegaConfig {
    pub workers: usize,
    pub schedulers: usize,
    /// Wall-clock safety net: `run` aborts (returning an error) if the
    /// graph has not drained in this long — surfaces scheduling bugs as
    /// test failures instead of hangs.
    pub timeout: Duration,
}

impl Default for MegaConfig {
    fn default() -> Self {
        // CPU-scale default: a few workers, one scheduler warp-group.
        MegaConfig { workers: 4, schedulers: 1, timeout: Duration::from_secs(60) }
    }
}

/// Anything that can execute task bodies. The scheduling runtime is
/// generic over this: a no-op executor measures pure runtime overhead,
/// `exec::TileExecutor` runs real numerics through PJRT.
pub trait TaskExecutor: Sync {
    fn execute(&self, task: &TaskDesc);
}

impl<F: Fn(&TaskDesc) + Sync> TaskExecutor for F {
    fn execute(&self, task: &TaskDesc) {
        self(task)
    }
}

/// Outcome of one mega-kernel invocation.
#[derive(Clone, Debug)]
pub struct RunReport {
    pub elapsed: Duration,
    pub metrics: MetricsSnapshot,
    /// Tasks executed per worker (load-balance diagnostics).
    pub per_worker_tasks: Vec<u64>,
}

/// The persistent mega-kernel over one compiled tGraph.
pub struct MegaKernel<'g> {
    graph: &'g CompiledGraph,
    cfg: MegaConfig,
    events: EventTable,
    /// Worker JIT queues (schedulers → worker).
    jit_queues: Vec<MpmcQueue<TaskId>>,
    /// Scheduler event queues (workers → scheduler).
    event_queues: Vec<MpmcQueue<usize>>,
    /// Round-robin cursor for JIT dispatch.
    dispatch_cursor: AtomicUsize,
    stop: AtomicBool,
    metrics: RuntimeMetrics,
    /// AOT assignment per worker, rebuilt per run (interior mutability so
    /// `run(&self)` can hand each worker its queue).
    aot_assignment: Vec<Mutex<AotQueue>>,
}

impl<'g> MegaKernel<'g> {
    pub fn new(graph: &'g CompiledGraph, cfg: MegaConfig) -> Self {
        assert!(cfg.workers >= 1 && cfg.schedulers >= 1);
        let nev = graph.tgraph.events.len();
        let required: Vec<usize> = (0..nev).map(|e| graph.linear.required[e]).collect();
        let ntasks = graph.tgraph.tasks.len();
        let jit_queues = (0..cfg.workers).map(|_| MpmcQueue::new(ntasks + 2)).collect();
        let event_queues = (0..cfg.schedulers).map(|_| MpmcQueue::new(nev + 2)).collect();
        let aot_assignment = (0..cfg.workers).map(|_| Mutex::new(AotQueue::default())).collect();
        MegaKernel {
            graph,
            cfg,
            events: EventTable::new(&required),
            jit_queues,
            event_queues,
            dispatch_cursor: AtomicUsize::new(0),
            stop: AtomicBool::new(false),
            metrics: RuntimeMetrics::default(),
            aot_assignment,
        }
    }

    /// Pre-enqueue all AOT tasks round-robin across workers in
    /// linearized order (§5.2 "All AOT tasks are pre-enqueued").
    fn pre_enqueue_aot(&self) {
        let tasks = &self.graph.tgraph.tasks;
        let mut per_worker: Vec<Vec<TaskId>> = vec![Vec::new(); self.cfg.workers];
        let mut cursor = 0usize;
        for &tid in &self.graph.linear.order {
            if tasks[tid].launch == LaunchMode::Aot {
                per_worker[cursor % self.cfg.workers].push(tid);
                cursor += 1;
            }
        }
        for (w, items) in per_worker.into_iter().enumerate() {
            *self.aot_assignment[w].lock().unwrap() = AotQueue::new(items);
        }
    }

    /// Execute the whole tGraph once. Returns a report, or an error
    /// string on timeout (stuck dependency — indicates a compiler bug).
    pub fn run<E: TaskExecutor>(&self, exec: &E) -> Result<RunReport, String> {
        self.events.reset();
        self.metrics.reset();
        self.stop.store(false, Ordering::Release);
        self.pre_enqueue_aot();

        // seed: the start event is born-activated; hand it to scheduler 0
        // so JIT successors launch, AOT successors see `activated()`.
        let start = self.graph.tgraph.start_event;
        self.event_queues[0].push(start).map_err(|_| "event queue full at seed".to_string())?;

        let per_worker_counts: Vec<AtomicUsize> =
            (0..self.cfg.workers).map(|_| AtomicUsize::new(0)).collect();
        let t0 = Instant::now();
        let deadline = t0 + self.cfg.timeout;

        std::thread::scope(|s| {
            for w in 0..self.cfg.workers {
                let counts = &per_worker_counts;
                s.spawn(move || self.worker_loop(w, exec, &counts[w], deadline));
            }
            for sc in 0..self.cfg.schedulers {
                s.spawn(move || self.scheduler_loop(sc, deadline));
            }
        });

        let elapsed = t0.elapsed();
        if !self.events.activated(self.graph.tgraph.end_event) {
            return Err(format!(
                "mega-kernel timed out after {elapsed:?}: end event not activated"
            ));
        }
        Ok(RunReport {
            elapsed,
            metrics: self.metrics.snapshot(),
            per_worker_tasks: per_worker_counts.iter().map(|c| c.load(Ordering::Relaxed) as u64).collect(),
        })
    }

    fn worker_loop<E: TaskExecutor>(
        &self,
        w: usize,
        exec: &E,
        count: &AtomicUsize,
        deadline: Instant,
    ) {
        let tasks = &self.graph.tgraph.tasks;
        let mut aot = self.aot_assignment[w].lock().unwrap();
        let mut idle: u32 = 0;
        loop {
            if self.stop.load(Ordering::Acquire) {
                break;
            }
            // 1. JIT queue has priority: those tasks are ready now.
            if let Some(tid) = self.jit_queues[w].pop() {
                self.run_task(&tasks[tid], exec);
                count.fetch_add(1, Ordering::Relaxed);
                idle = 0;
                continue;
            }
            // 2. AOT head, if its dependent event is activated.
            if let Some(tid) = aot.peek() {
                let dep = tasks[tid].dependent_events[0];
                if self.events.activated(dep) {
                    aot.advance();
                    self.metrics.inc(&self.metrics.aot_hits);
                    self.run_task(&tasks[tid], exec);
                    count.fetch_add(1, Ordering::Relaxed);
                    idle = 0;
                    continue;
                }
            }
            // 3. idle: spin briefly, then yield; check the watchdog.
            self.metrics.inc(&self.metrics.worker_idle_spins);
            idle += 1;
            if idle % 64 == 0 {
                std::thread::yield_now();
                if Instant::now() > deadline {
                    self.stop.store(true, Ordering::Release);
                    break;
                }
            } else {
                std::hint::spin_loop();
            }
        }
    }

    fn scheduler_loop(&self, sc: usize, deadline: Instant) {
        let tgraph = &self.graph.tgraph;
        let linear = &self.graph.linear;
        let mut idle: u32 = 0;
        loop {
            if self.stop.load(Ordering::Acquire) {
                break;
            }
            match self.event_queues[sc].pop() {
                Some(ev) => {
                    idle = 0;
                    let t0 = Instant::now();
                    // dispatch the event's JIT successors; range encoding
                    // from linearization gives them contiguously.
                    if let Some((first, last)) = linear.event_range[ev] {
                        for pos in first..=last {
                            let tid = linear.order[pos];
                            if tgraph.tasks[tid].launch == LaunchMode::Jit {
                                self.dispatch_jit(tid);
                            }
                        }
                    }
                    self.metrics
                        .sched_ns
                        .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                }
                None => {
                    self.metrics.inc(&self.metrics.sched_idle_spins);
                    idle += 1;
                    if idle % 64 == 0 {
                        std::thread::yield_now();
                        if Instant::now() > deadline {
                            self.stop.store(true, Ordering::Release);
                            break;
                        }
                    } else {
                        std::hint::spin_loop();
                    }
                }
            }
        }
    }

    /// Round-robin JIT dispatch with a shortest-queue refinement over a
    /// small probe window (decentralized, local state only — §6.1).
    fn dispatch_jit(&self, tid: TaskId) {
        self.metrics.inc(&self.metrics.jit_dispatches);
        let n = self.cfg.workers;
        let base = self.dispatch_cursor.fetch_add(1, Ordering::Relaxed);
        let mut best = base % n;
        let mut best_len = self.jit_queues[best].len_approx();
        for probe in 1..3.min(n) {
            let cand = (base + probe) % n;
            let l = self.jit_queues[cand].len_approx();
            if l < best_len {
                best = cand;
                best_len = l;
            }
        }
        let mut target = best;
        while self.jit_queues[target].push(tid).is_err() {
            // queue sized to total task count: full should be impossible,
            // but fall over to the next worker defensively.
            target = (target + 1) % n;
        }
    }

    fn run_task<E: TaskExecutor>(&self, task: &TaskDesc, exec: &E) {
        let t0 = Instant::now();
        if task.kind.is_dummy() {
            self.metrics.inc(&self.metrics.dummy_tasks);
        } else {
            exec.execute(task);
        }
        self.metrics.inc(&self.metrics.tasks_executed);
        self.metrics.task_ns.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        // notify the triggering event (exactly one — graph is normalized).
        if let Some(&ev) = task.trigger_events.first() {
            if self.events.notify(ev) {
                self.on_activation(ev);
            }
        }
    }

    fn on_activation(&self, ev: usize) {
        self.metrics.inc(&self.metrics.events_activated);
        if ev == self.graph.tgraph.end_event {
            self.stop.store(true, Ordering::Release);
            return;
        }
        // hand to a scheduler only if the event launches JIT tasks; pure
        // AOT successors are found by their workers via `activated()`.
        let linear = &self.graph.linear;
        let has_jit = linear.event_range[ev]
            .map(|(f, l)| {
                (f..=l).any(|p| self.graph.tgraph.tasks[linear.order[p]].launch == LaunchMode::Jit)
            })
            .unwrap_or(false);
        if has_jit {
            let sc = ev % self.cfg.schedulers;
            let mut target = sc;
            while self.event_queues[target].push(ev).is_err() {
                target = (target + 1) % self.cfg.schedulers;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{build_decode_graph, GraphOptions, ModelConfig};
    use crate::tgraph::{compile, CompileOptions, DecomposeConfig};
    use std::collections::HashSet;
    use std::sync::Mutex as StdMutex;

    fn compiled_tiny(batch: usize) -> CompiledGraph {
        let cfg = ModelConfig::tiny();
        let g = build_decode_graph(&cfg, &GraphOptions { batch, kv_len: 16, ..Default::default() });
        compile(
            &g,
            &CompileOptions {
                decompose: DecomposeConfig { target_tasks: 8, min_tile_cols: 8 },
                ..Default::default()
            },
        )
    }

    #[test]
    fn executes_every_task_exactly_once() {
        let c = compiled_tiny(2);
        let mk = MegaKernel::new(&c, MegaConfig { workers: 4, schedulers: 2, ..Default::default() });
        let seen = StdMutex::new(Vec::new());
        let report = mk.run(&|t: &TaskDesc| seen.lock().unwrap().push(t.id)).unwrap();
        let seen = seen.lock().unwrap();
        let uniq: HashSet<_> = seen.iter().copied().collect();
        assert_eq!(uniq.len(), seen.len(), "a task ran twice");
        // every non-dummy task ran (dummies are skipped by the executor
        // wrapper but still counted in metrics).
        let expected = c.tgraph.real_task_count();
        assert_eq!(seen.len(), expected);
        assert_eq!(
            report.metrics.tasks_executed as usize,
            c.tgraph.tasks.len(),
            "dummy + real tasks all pass through the runtime"
        );
    }

    #[test]
    fn respects_topological_order() {
        let c = compiled_tiny(1);
        let mk = MegaKernel::new(&c, MegaConfig { workers: 3, schedulers: 1, ..Default::default() });
        // record completion order positions; a consumer must complete
        // after every producer its dependent event waits on.
        let order = StdMutex::new(Vec::new());
        mk.run(&|t: &TaskDesc| order.lock().unwrap().push(t.id)).unwrap();
        let order = order.lock().unwrap();
        let pos: std::collections::HashMap<usize, usize> =
            order.iter().enumerate().map(|(i, &t)| (t, i)).collect();
        for t in &c.tgraph.tasks {
            if t.kind.is_dummy() {
                continue;
            }
            let dep = t.dependent_events[0];
            for &p in &c.tgraph.events[dep].in_tasks {
                if c.tgraph.tasks[p].kind.is_dummy() {
                    continue;
                }
                assert!(
                    pos[&p] < pos[&t.id],
                    "task {} ran before its producer {}",
                    t.id,
                    p
                );
            }
        }
    }

    #[test]
    fn single_worker_single_scheduler_works() {
        let c = compiled_tiny(1);
        let mk = MegaKernel::new(&c, MegaConfig { workers: 1, schedulers: 1, ..Default::default() });
        let n = StdMutex::new(0usize);
        mk.run(&|_: &TaskDesc| *n.lock().unwrap() += 1).unwrap();
        assert_eq!(*n.lock().unwrap(), c.tgraph.real_task_count());
    }

    #[test]
    fn rerun_reuses_kernel() {
        let c = compiled_tiny(2);
        let mk = MegaKernel::new(&c, MegaConfig::default());
        for _ in 0..3 {
            let r = mk.run(&|_: &TaskDesc| {}).unwrap();
            assert_eq!(r.metrics.tasks_executed as usize, c.tgraph.tasks.len());
        }
    }

    #[test]
    fn jit_and_aot_paths_both_used() {
        let c = compiled_tiny(4);
        let mk = MegaKernel::new(&c, MegaConfig { workers: 4, schedulers: 1, ..Default::default() });
        let r = mk.run(&|_: &TaskDesc| {}).unwrap();
        assert!(r.metrics.jit_dispatches > 0, "no JIT dispatches");
        assert!(r.metrics.aot_hits > 0, "no AOT hits");
    }

    #[test]
    fn load_reasonably_balanced() {
        let c = compiled_tiny(4);
        let mk = MegaKernel::new(&c, MegaConfig { workers: 4, schedulers: 1, ..Default::default() });
        // simulate non-trivial work so balancing matters.
        let r = mk
            .run(&|_: &TaskDesc| {
                std::hint::black_box((0..500).sum::<u64>());
            })
            .unwrap();
        let total: u64 = r.per_worker_tasks.iter().sum();
        assert_eq!(total as usize, c.tgraph.tasks.len());
        for (w, &n) in r.per_worker_tasks.iter().enumerate() {
            assert!(n > 0, "worker {w} starved entirely");
        }
    }
}
