//! Atomic event state — the device-memory semaphores of §5.1.
//!
//! Each event holds a trigger counter; a task completing does one
//! `fetch_add` (the paper's `atomicAdd`). The notification that crosses
//! the activation threshold is the one that enqueues the event for a
//! scheduler (JIT) — AOT consumers instead poll [`EventTable::activated`]
//! on their queue head.

use std::sync::atomic::{AtomicU32, Ordering};

/// Runtime counters for all events of one tGraph execution.
pub struct EventTable {
    counters: Vec<AtomicU32>,
    required: Vec<u32>,
}

impl EventTable {
    pub fn new(required: &[usize]) -> Self {
        EventTable {
            counters: required.iter().map(|_| AtomicU32::new(0)).collect(),
            required: required.iter().map(|&r| r as u32).collect(),
        }
    }

    /// Reset all counters (reuse across decode iterations).
    pub fn reset(&self) {
        for c in &self.counters {
            c.store(0, Ordering::Relaxed);
        }
    }

    pub fn len(&self) -> usize {
        self.counters.len()
    }

    pub fn is_empty(&self) -> bool {
        self.counters.is_empty()
    }

    /// Notify the event once. Returns `true` iff *this* notification
    /// activated the event (exactly one caller observes `true`).
    pub fn notify(&self, ev: usize) -> bool {
        let prev = self.counters[ev].fetch_add(1, Ordering::AcqRel);
        prev + 1 == self.required[ev]
    }

    /// True once the event has received all required notifications.
    /// Events with `required == 0` (the start event) are born activated.
    pub fn activated(&self, ev: usize) -> bool {
        self.counters[ev].load(Ordering::Acquire) >= self.required[ev]
    }

    pub fn required(&self, ev: usize) -> u32 {
        self.required[ev]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn activation_threshold() {
        let t = EventTable::new(&[3]);
        assert!(!t.activated(0));
        assert!(!t.notify(0));
        assert!(!t.notify(0));
        assert!(t.notify(0)); // third notification crosses the threshold
        assert!(t.activated(0));
    }

    #[test]
    fn zero_required_is_born_activated() {
        let t = EventTable::new(&[0]);
        assert!(t.activated(0));
    }

    #[test]
    fn exactly_one_activator_under_contention() {
        let t = Arc::new(EventTable::new(&[64]));
        let activations: usize = std::thread::scope(|s| {
            let handles: Vec<_> = (0..8)
                .map(|_| {
                    let t = t.clone();
                    s.spawn(move || (0..8).filter(|_| t.notify(0)).count())
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).sum()
        });
        assert_eq!(activations, 1);
        assert!(t.activated(0));
    }

    #[test]
    fn reset_clears_state() {
        let t = EventTable::new(&[1, 2]);
        t.notify(0);
        assert!(t.activated(0));
        t.reset();
        assert!(!t.activated(0));
    }
}
