//! Static race & deadlock verifier for compiled SM-level task graphs.
//!
//! The whole zero-copy memory model (see the `exec::store` memory-model
//! note) rests on one compiler invariant: an event edge exists whenever
//! a producer's output region overlaps a consumer's input region
//! (§4.1), so the in-kernel runtime's acquire/release event activation
//! establishes every writer-before-reader ordering. This module checks
//! that invariant *independently* of the pipeline that is supposed to
//! enforce it:
//!
//! 1. **Race detection** ([`check_races`]) — re-derives every task's
//!    read/write footprint from the operator vocabulary alone (write =
//!    the task's `out_region` on its op's output tensor; reads =
//!    [`crate::ops::OpKind::input_region`] per input; `Transfer` re-publishes its
//!    operator's output; `Dummy`/`IterPrep` have no arena footprint)
//!    and requires every overlapping write/write and write/read pair on
//!    the same tensor to be ordered by the happens-before relation of
//!    the bipartite task/event DAG (a per-task reachability bitset
//!    closure, [`hb_closure`]).
//! 2. **Deadlock / liveness** ([`check_liveness`]) — the graph is
//!    acyclic, every event's trigger count is satisfiable from the
//!    start event (forward activation simulation), every task runs,
//!    every task reaches the end event (quiescence is signaled only
//!    after *all* work), and the end event launches nothing.
//! 3. **Transform preservation** ([`check_stage_preservation`]) — each
//!    pipeline stage's pre/post graphs induce compatible happens-before
//!    relations: fusion and fork-merging may only *add* orderings,
//!    normalization must preserve the relation between real tasks
//!    exactly (dummy insertion is pure re-encoding), and the
//!    linearized form must agree with the event lists
//!    ([`check_linearization`]).
//! 4. **Ablation honesty** ([`check_ablation_superset`]) — a graph
//!    compiled under `DepGranularity::CoarseAll` / `CoarseCollectives`
//!    must order a *superset* of what `Fine` orders, so ablation
//!    numbers can never come from an under-synchronized graph.
//!
//! The analyzer itself is validated by **mutation testing**
//! ([`mutate`], [`mutation_sweep`]): a seeded edge-dropper/redirector
//! deletes or rewires one event edge of a known-good graph and asserts
//! the race or liveness analysis fires — a verifier that passes
//! everything is worthless.

use crate::ops::{CompGraph, Region, TensorId};
use crate::tgraph::build::OpTasks;
use crate::tgraph::compiler::task_label;
use crate::tgraph::linearize::LinearTGraph;
use crate::tgraph::task::{EventDesc, EventId, TaskDesc, TaskId, TaskKind, TGraph};
use crate::util::XorShift64;
use std::collections::HashSet;
use std::time::Instant;

// ---------------------------------------------------------------------------
// Violations and the report
// ---------------------------------------------------------------------------

/// Which aliasing rule an unordered pair breaks.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RaceKind {
    /// Two writes to overlapping regions with no ordering in either
    /// direction.
    WriteWrite,
    /// A read overlapping a write with no writer-before-reader path.
    WriteRead,
}

/// One verifier finding. `Display` renders a diagnosis with task
/// labels, tensor names and both regions where applicable.
#[derive(Clone, Debug)]
pub enum Violation {
    /// An overlapping region pair the happens-before relation fails to
    /// order (`first` is the writer for [`RaceKind::WriteRead`]).
    Race {
        kind: RaceKind,
        tensor: String,
        first: TaskId,
        first_label: String,
        first_region: Region,
        second: TaskId,
        second_label: String,
        second_region: Region,
    },
    /// The task/event graph cannot drain from the start event:
    /// a cycle, an unsatisfiable event, or a task that never runs.
    Deadlock { detail: String },
    /// A task (or event) that can never be scheduled or whose
    /// completion is invisible to the end event.
    Liveness { detail: String },
    /// A pipeline stage lost or illegally added a task ordering.
    StagePreservation { stage: String, detail: String },
    /// A coarse-granularity relation failed to cover the fine one.
    Ablation { detail: String },
    /// The linearized encoding disagrees with the event lists.
    Linearization { detail: String },
}

impl std::fmt::Display for Violation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Violation::Race {
                kind,
                tensor,
                first,
                first_label,
                first_region,
                second,
                second_label,
                second_region,
            } => {
                let k = match kind {
                    RaceKind::WriteWrite => "write/write",
                    RaceKind::WriteRead => "write/read",
                };
                write!(
                    f,
                    "{k} race on tensor `{tensor}`: task {first} ({first_label}) region \
                     {first_region} vs task {second} ({second_label}) region {second_region} \
                     — no happens-before path orders them"
                )
            }
            Violation::Deadlock { detail } => write!(f, "deadlock: {detail}"),
            Violation::Liveness { detail } => write!(f, "liveness: {detail}"),
            Violation::StagePreservation { stage, detail } => {
                write!(f, "stage `{stage}` broke the happens-before relation: {detail}")
            }
            Violation::Ablation { detail } => write!(f, "ablation honesty: {detail}"),
            Violation::Linearization { detail } => write!(f, "linearization: {detail}"),
        }
    }
}

/// Outcome of a verification run, plus the coverage stats surfaced in
/// [`crate::tgraph::StageStats`] and `mpk verify`.
#[derive(Clone, Debug, Default)]
pub struct VerifyReport {
    pub tasks: usize,
    pub events: usize,
    /// Direct task→task ordered pairs encoded by the event lists
    /// (Σ |in_tasks|·|out_tasks|).
    pub hb_edges: usize,
    /// Overlapping same-tensor region pairs checked for ordering.
    pub region_pairs: usize,
    pub violations: Vec<Violation>,
    /// Verifier wall time, µs.
    pub wall_us: u64,
}

impl VerifyReport {
    pub fn is_clean(&self) -> bool {
        self.violations.is_empty()
    }

    /// One-line outcome summary.
    pub fn summary(&self) -> String {
        format!(
            "{} tasks, {} events, {} hb-edges, {} region pairs checked, {} violation(s), {} µs",
            self.tasks,
            self.events,
            self.hb_edges,
            self.region_pairs,
            self.violations.len(),
            self.wall_us
        )
    }

    /// Render up to `max` violations, one per line.
    pub fn render(&self, max: usize) -> String {
        let mut s = self.summary();
        for v in self.violations.iter().take(max) {
            s.push_str("\n  - ");
            s.push_str(&v.to_string());
        }
        if self.violations.len() > max {
            s.push_str(&format!("\n  … and {} more", self.violations.len() - max));
        }
        s
    }
}

// ---------------------------------------------------------------------------
// Happens-before closure
// ---------------------------------------------------------------------------

/// Transitive happens-before relation over tasks, as one reachability
/// bitset row per task (columns restricted to tasks `< n_cols` so a
/// stage comparison can ignore dummies appended by later stages).
pub struct HbClosure {
    n_cols: usize,
    words_per_row: usize,
    bits: Vec<u64>,
}

impl HbClosure {
    /// True iff `from` strictly happens-before `to` (`to < n_cols`).
    pub fn ordered(&self, from: TaskId, to: TaskId) -> bool {
        debug_assert!(to < self.n_cols);
        self.bits[from * self.words_per_row + (to >> 6)] & (1u64 << (to & 63)) != 0
    }

    fn row(&self, t: TaskId) -> &[u64] {
        &self.bits[t * self.words_per_row..(t + 1) * self.words_per_row]
    }
}

/// Compute the happens-before closure of a bipartite task/event DAG.
/// Task `p` happens-before task `c` iff an event path leads from `p`'s
/// trigger events to `c`. Errors if the graph is cyclic.
pub fn hb_closure(
    tasks: &[TaskDesc],
    events: &[EventDesc],
    n_cols: usize,
) -> Result<HbClosure, String> {
    let n = tasks.len();
    // Kahn over tasks: in-degree = total notifications feeding the
    // task's dependent events' in-task lists... direct task in-degree is
    // the number of (producer, this) edges.
    let mut indeg = vec![0usize; n];
    for e in events {
        for &c in &e.out_tasks {
            indeg[c] += e.in_tasks.len();
        }
    }
    let mut queue: std::collections::VecDeque<TaskId> =
        (0..n).filter(|&t| indeg[t] == 0).collect();
    let mut topo: Vec<TaskId> = Vec::with_capacity(n);
    while let Some(t) = queue.pop_front() {
        topo.push(t);
        for &e in &tasks[t].trigger_events {
            for &c in &events[e].out_tasks {
                indeg[c] -= 1;
                if indeg[c] == 0 {
                    queue.push_back(c);
                }
            }
        }
    }
    if topo.len() != n {
        return Err(format!("task/event graph has a cycle ({} tasks unplaced)", n - topo.len()));
    }

    let words_per_row = n_cols.div_ceil(64).max(1);
    let mut bits = vec![0u64; n * words_per_row];
    let mut acc = vec![0u64; words_per_row];
    for &t in topo.iter().rev() {
        acc.iter_mut().for_each(|w| *w = 0);
        for &e in &tasks[t].trigger_events {
            for &c in &events[e].out_tasks {
                if c < n_cols {
                    acc[c >> 6] |= 1u64 << (c & 63);
                }
                let crow = &bits[c * words_per_row..(c + 1) * words_per_row];
                for (a, b) in acc.iter_mut().zip(crow.iter()) {
                    *a |= *b;
                }
            }
        }
        bits[t * words_per_row..(t + 1) * words_per_row].copy_from_slice(&acc);
    }
    Ok(HbClosure { n_cols, words_per_row, bits })
}

/// Direct task→task pairs encoded by an event list.
pub fn hb_edge_count(events: &[EventDesc]) -> usize {
    events.iter().map(|e| e.in_tasks.len() * e.out_tasks.len()).sum()
}

// ---------------------------------------------------------------------------
// Footprint re-derivation
// ---------------------------------------------------------------------------

/// The region a task writes, re-derived from the operator vocabulary
/// (independent of the event edges under test). `Dummy` and `IterPrep`
/// tasks touch no arena memory.
pub fn task_write(g: &CompGraph, t: &TaskDesc) -> Option<(TensorId, Region)> {
    match &t.kind {
        TaskKind::Compute { op, .. } => Some((g.ops[*op].output, t.out_region.clone())),
        // A transfer re-publishes (a tile of) its operator's output on
        // another device: model it as a write of that tile.
        TaskKind::Transfer { op, .. } => {
            let out = g.ops[*op].output;
            let r = if t.out_region.dims.is_empty() {
                g.tensor(out).full_region()
            } else {
                t.out_region.clone()
            };
            Some((out, r))
        }
        TaskKind::Dummy | TaskKind::IterPrep => None,
    }
}

/// The regions a task reads, re-derived via [`crate::ops::OpKind::input_region`].
pub fn task_reads(g: &CompGraph, t: &TaskDesc) -> Vec<(TensorId, Region)> {
    match &t.kind {
        TaskKind::Compute { op, kind } => {
            let o = &g.ops[*op];
            o.inputs
                .iter()
                .enumerate()
                .map(|(idx, &inp)| {
                    (inp, kind.input_region(&t.out_region, idx, &g.tensor(inp).shape))
                })
                .collect()
        }
        // The transfer's source is the same tile it re-publishes.
        TaskKind::Transfer { op, .. } => {
            let out = g.ops[*op].output;
            let r = if t.out_region.dims.is_empty() {
                g.tensor(out).full_region()
            } else {
                t.out_region.clone()
            };
            vec![(out, r)]
        }
        TaskKind::Dummy | TaskKind::IterPrep => Vec::new(),
    }
}

/// Outcome of the race analysis.
pub struct RaceAnalysis {
    pub violations: Vec<Violation>,
    pub region_pairs: usize,
    pub hb_edges: usize,
}

/// Race detection (analysis 1): every overlapping write/write and
/// write/read region pair on the same tensor must be connected by a
/// happens-before path. A cyclic graph is reported as a deadlock here
/// (no ordering exists at all) and left for [`check_liveness`] to
/// localize.
pub fn check_races(g: &CompGraph, tasks: &[TaskDesc], events: &[EventDesc]) -> RaceAnalysis {
    let hb_edges = hb_edge_count(events);
    let closure = match hb_closure(tasks, events, tasks.len()) {
        Ok(c) => c,
        Err(detail) => {
            return RaceAnalysis {
                violations: vec![Violation::Deadlock { detail }],
                region_pairs: 0,
                hb_edges,
            }
        }
    };

    // writer lists per tensor (single-producer IR: one op's tasks).
    let mut writers: Vec<Vec<(TaskId, Region)>> = vec![Vec::new(); g.tensors.len()];
    for t in tasks {
        if let Some((tid, r)) = task_write(g, t) {
            if !r.is_empty() {
                writers[tid].push((t.id, r));
            }
        }
    }

    let mut violations = Vec::new();
    let mut region_pairs = 0usize;
    let label = |t: TaskId| task_label(g, &tasks[t]);

    // write/write: overlapping writer tiles of one tensor must be
    // ordered in *some* direction.
    for (tid, ws) in writers.iter().enumerate() {
        for i in 0..ws.len() {
            for j in i + 1..ws.len() {
                let (a, ra) = &ws[i];
                let (b, rb) = &ws[j];
                if !ra.overlaps(rb) {
                    continue;
                }
                region_pairs += 1;
                if !closure.ordered(*a, *b) && !closure.ordered(*b, *a) {
                    violations.push(Violation::Race {
                        kind: RaceKind::WriteWrite,
                        tensor: g.tensor(tid).name.clone(),
                        first: *a,
                        first_label: label(*a),
                        first_region: ra.clone(),
                        second: *b,
                        second_label: label(*b),
                        second_region: rb.clone(),
                    });
                }
            }
        }
    }

    // write/read: the writer must happen-before the reader (value
    // semantics — a reader racing ahead observes garbage).
    let mut seen: HashSet<(TaskId, TaskId)> = HashSet::new();
    for t in tasks {
        for (tensor, rr) in task_reads(g, t) {
            if rr.is_empty() {
                continue;
            }
            let ws = &writers[tensor];
            for (w, wr) in ws {
                if *w == t.id || !wr.overlaps(&rr) {
                    continue;
                }
                if !seen.insert((*w, t.id)) {
                    continue;
                }
                region_pairs += 1;
                if !closure.ordered(*w, t.id) {
                    violations.push(Violation::Race {
                        kind: RaceKind::WriteRead,
                        tensor: g.tensor(tensor).name.clone(),
                        first: *w,
                        first_label: label(*w),
                        first_region: wr.clone(),
                        second: t.id,
                        second_label: label(t.id),
                        second_region: rr.clone(),
                    });
                }
            }
        }
    }

    RaceAnalysis { violations, region_pairs, hb_edges }
}

// ---------------------------------------------------------------------------
// Liveness / deadlock
// ---------------------------------------------------------------------------

/// Deadlock & liveness (analysis 2): forward activation simulation from
/// the start event plus a reverse reachability pass from the end event.
pub fn check_liveness(tg: &TGraph) -> Vec<Violation> {
    let mut violations = Vec::new();
    let tasks = &tg.tasks;
    let events = &tg.events;

    if !events[tg.start_event].in_tasks.is_empty() {
        violations.push(Violation::Liveness {
            detail: format!("start event {} has in-tasks", tg.start_event),
        });
    }
    if !events[tg.end_event].out_tasks.is_empty() {
        violations.push(Violation::Liveness {
            detail: format!(
                "end event {} launches {} task(s) — they would run after quiescence is signaled",
                tg.end_event,
                events[tg.end_event].out_tasks.len()
            ),
        });
    }

    // Forward simulation: activate the start event, run launched tasks,
    // count notifications; an event activates exactly when its
    // required_triggers notifications have arrived.
    let mut notified = vec![0usize; events.len()];
    let mut activated = vec![false; events.len()];
    let mut ran = vec![false; tasks.len()];
    let mut queue: std::collections::VecDeque<EventId> = std::collections::VecDeque::new();
    activated[tg.start_event] = true;
    queue.push_back(tg.start_event);
    while let Some(e) = queue.pop_front() {
        for &t in &events[e].out_tasks {
            if ran[t] {
                continue;
            }
            // a task runs when its (sole, post-normalization) dependent
            // events have all activated.
            if !tasks[t].dependent_events.iter().all(|&d| activated[d]) {
                continue;
            }
            ran[t] = true;
            for &te in &tasks[t].trigger_events {
                notified[te] += 1;
                if !activated[te] && notified[te] == events[te].required_triggers() {
                    activated[te] = true;
                    queue.push_back(te);
                }
            }
        }
    }
    for (e, ev) in events.iter().enumerate() {
        if notified[e] > ev.required_triggers() {
            violations.push(Violation::Liveness {
                detail: format!(
                    "event {e} over-notified: {} notifications for {} required",
                    notified[e],
                    ev.required_triggers()
                ),
            });
        }
        if !activated[e] && !ev.out_tasks.is_empty() {
            violations.push(Violation::Deadlock {
                detail: format!(
                    "event {e} never activates ({}/{} triggers arrive) but launches {} task(s)",
                    notified[e],
                    ev.required_triggers(),
                    ev.out_tasks.len()
                ),
            });
        }
    }
    let unran: Vec<TaskId> = (0..tasks.len()).filter(|&t| !ran[t]).collect();
    if !unran.is_empty() {
        violations.push(Violation::Deadlock {
            detail: format!(
                "{} task(s) never run (cycle or unsatisfiable prerequisites), e.g. task {}",
                unran.len(),
                unran[0]
            ),
        });
    }
    if !activated[tg.end_event] {
        violations.push(Violation::Deadlock {
            detail: format!(
                "end event {} never activates — the runtime would never detect quiescence",
                tg.end_event
            ),
        });
    }

    // Reverse reachability: every task must reach the end event, or the
    // runtime signals completion while work is still outstanding.
    let mut task_reaches = vec![false; tasks.len()];
    let mut event_reaches = vec![false; events.len()];
    let mut stack: Vec<EventId> = vec![tg.end_event];
    event_reaches[tg.end_event] = true;
    while let Some(e) = stack.pop() {
        for &t in &events[e].in_tasks {
            if task_reaches[t] {
                continue;
            }
            task_reaches[t] = true;
            for &d in &tasks[t].dependent_events {
                if !event_reaches[d] {
                    event_reaches[d] = true;
                    stack.push(d);
                }
            }
        }
    }
    let lost: Vec<TaskId> = (0..tasks.len()).filter(|&t| !task_reaches[t]).collect();
    if !lost.is_empty() {
        violations.push(Violation::Liveness {
            detail: format!(
                "{} task(s) never reach the end event (completion invisible to quiescence), \
                 e.g. task {}",
                lost.len(),
                lost[0]
            ),
        });
    }
    violations
}

// ---------------------------------------------------------------------------
// Transform preservation
// ---------------------------------------------------------------------------

/// How a stage's happens-before relation must relate to its
/// predecessor's, restricted to the tasks both stages share.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StageRule {
    /// The stage may add orderings but must not lose any
    /// (event fusion, fork merging, coarsening).
    Superset,
    /// The stage must preserve the relation exactly (normalization:
    /// dummy insertion is pure re-encoding).
    Exact,
}

/// One pipeline stage's task/event lists, captured by the compiler when
/// verification is enabled.
#[derive(Clone)]
pub struct StageSnapshot {
    pub stage: &'static str,
    pub rule: StageRule,
    pub tasks: Vec<TaskDesc>,
    pub events: Vec<EventDesc>,
}

/// Transform preservation (analysis 3): adjacent stage snapshots must
/// induce compatible happens-before relations over the real tasks of
/// the first stage (later stages only append dummy tasks).
pub fn check_stage_preservation(snapshots: &[StageSnapshot]) -> Vec<Violation> {
    let mut violations = Vec::new();
    let Some(first) = snapshots.first() else { return violations };
    let n0 = first.tasks.len();
    let mut prev: Option<(&'static str, HbClosure)> = None;
    for snap in snapshots {
        let closure = match hb_closure(&snap.tasks, &snap.events, n0) {
            Ok(c) => c,
            Err(detail) => {
                violations.push(Violation::StagePreservation {
                    stage: snap.stage.to_string(),
                    detail,
                });
                return violations;
            }
        };
        if let Some((pstage, pclosure)) = prev.take() {
            if let Some(v) = compare_relations(pstage, &pclosure, snap, &closure, n0) {
                violations.push(v);
            }
        }
        prev = Some((snap.stage, closure));
    }
    violations
}

/// Compare two stage relations under `cur.rule`; returns the first
/// discrepancy found.
fn compare_relations(
    prev_stage: &str,
    prev_cl: &HbClosure,
    cur: &StageSnapshot,
    cur_cl: &HbClosure,
    n0: usize,
) -> Option<Violation> {
    for t in 0..n0 {
        let pr = prev_cl.row(t);
        let cr = cur_cl.row(t);
        for (w, (pw, cw)) in pr.iter().zip(cr.iter()).enumerate() {
            // lost: ordered before, unordered after.
            let lost = pw & !cw;
            if lost != 0 {
                let u = (w << 6) + lost.trailing_zeros() as usize;
                return Some(Violation::StagePreservation {
                    stage: cur.stage.to_string(),
                    detail: format!(
                        "ordering {t} -> {u} present after `{}` but lost after `{}`",
                        prev_stage, cur.stage
                    ),
                });
            }
            if cur.rule == StageRule::Exact {
                let added = cw & !pw;
                if added != 0 {
                    let u = (w << 6) + added.trailing_zeros() as usize;
                    return Some(Violation::StagePreservation {
                        stage: cur.stage.to_string(),
                        detail: format!(
                            "ordering {t} -> {u} added by `{}` beyond transitivity of `{}`",
                            cur.stage, prev_stage
                        ),
                    });
                }
            }
        }
    }
    None
}

/// Ablation honesty (analysis 4): the relation of a coarse-granularity
/// raw stage must be a superset of the fine-grained relation derived
/// from the same decomposition.
pub fn check_ablation_superset(
    g: &CompGraph,
    decomp: &[OpTasks],
    coarse: &StageSnapshot,
) -> Vec<Violation> {
    let fine = crate::tgraph::build::analyze_deps(g, decomp);
    let n0 = fine.tasks.len();
    if n0 != coarse.tasks.len() {
        return vec![Violation::Ablation {
            detail: format!(
                "task count mismatch: fine {} vs coarse {}",
                n0,
                coarse.tasks.len()
            ),
        }];
    }
    let fine_cl = match hb_closure(&fine.tasks, &fine.events, n0) {
        Ok(c) => c,
        Err(detail) => return vec![Violation::Ablation { detail }],
    };
    let coarse_cl = match hb_closure(&coarse.tasks, &coarse.events, n0) {
        Ok(c) => c,
        Err(detail) => return vec![Violation::Ablation { detail }],
    };
    for t in 0..n0 {
        let fr = fine_cl.row(t);
        let cr = coarse_cl.row(t);
        for (w, (fw, cw)) in fr.iter().zip(cr.iter()).enumerate() {
            let lost = fw & !cw;
            if lost != 0 {
                let u = (w << 6) + lost.trailing_zeros() as usize;
                return vec![Violation::Ablation {
                    detail: format!(
                        "coarse granularity loses fine ordering {t} -> {u} — the ablation \
                         would run an under-synchronized graph"
                    ),
                }];
            }
        }
    }
    Vec::new()
}

// ---------------------------------------------------------------------------
// Linearization agreement
// ---------------------------------------------------------------------------

/// Linearized-encoding agreement: the `(first, last)` ranges and
/// trigger counts must round-trip the event lists, and the launch order
/// must be a topological order of the happens-before relation.
pub fn check_linearization(
    lin: &LinearTGraph,
    tasks: &[TaskDesc],
    events: &[EventDesc],
) -> Vec<Violation> {
    let mut violations = Vec::new();
    if let Err(detail) = crate::tgraph::linearize::verify(lin, tasks, events) {
        violations.push(Violation::Linearization { detail });
    }
    for e in events {
        if lin.required.get(e.id).copied() != Some(e.required_triggers()) {
            violations.push(Violation::Linearization {
                detail: format!(
                    "event {} required-trigger count {:?} disagrees with in-task list ({})",
                    e.id,
                    lin.required.get(e.id),
                    e.required_triggers()
                ),
            });
        }
        for &p in &e.in_tasks {
            for &c in &e.out_tasks {
                if lin.pos[p] >= lin.pos[c] {
                    violations.push(Violation::Linearization {
                        detail: format!(
                            "launch order places consumer task {c} (pos {}) before its \
                             producer task {p} (pos {})",
                            lin.pos[c], lin.pos[p]
                        ),
                    });
                }
            }
        }
    }
    violations
}

// ---------------------------------------------------------------------------
// Entry points
// ---------------------------------------------------------------------------

/// Verify a fully compiled graph: race detection, liveness, and
/// linearization agreement. Stage-preservation and ablation checks need
/// the compiler's intermediate snapshots — use
/// [`crate::tgraph::compile_verified`] for the full gate.
pub fn verify_compiled(c: &crate::tgraph::CompiledGraph) -> VerifyReport {
    let t0 = Instant::now();
    let tg = &c.tgraph;
    let mut report = VerifyReport {
        tasks: tg.tasks.len(),
        events: tg.events.len(),
        ..Default::default()
    };
    let races = check_races(&c.graph, &tg.tasks, &tg.events);
    report.hb_edges = races.hb_edges;
    report.region_pairs = races.region_pairs;
    report.violations = races.violations;
    report.violations.extend(check_liveness(tg));
    report.violations.extend(check_linearization(&c.linear, &tg.tasks, &tg.events));
    report.wall_us = t0.elapsed().as_micros() as u64;
    report
}

/// Race + liveness only, on bare task/event lists (used by the mutation
/// harness, which perturbs graphs that no longer linearize).
pub fn verify_graph(g: &CompGraph, tg: &TGraph) -> VerifyReport {
    let t0 = Instant::now();
    let races = check_races(g, &tg.tasks, &tg.events);
    let mut violations = races.violations;
    violations.extend(check_liveness(tg));
    VerifyReport {
        tasks: tg.tasks.len(),
        events: tg.events.len(),
        hb_edges: races.hb_edges,
        region_pairs: races.region_pairs,
        violations,
        wall_us: t0.elapsed().as_micros() as u64,
    }
}

/// The full compile-time gate: everything [`verify_compiled`] checks,
/// plus transform preservation across the compiler's captured stage
/// snapshots (with the final normalized graph appended under the
/// exact-preservation rule) and, under a coarse
/// [`crate::tgraph::DepGranularity`], the ablation-honesty superset
/// check against a freshly derived fine-grained relation.
pub fn verify_pipeline(
    c: &crate::tgraph::CompiledGraph,
    snapshots: &[StageSnapshot],
    opt: &crate::tgraph::CompileOptions,
) -> VerifyReport {
    let t0 = Instant::now();
    let mut report = verify_compiled(c);
    let mut chain: Vec<StageSnapshot> = snapshots.to_vec();
    chain.push(StageSnapshot {
        stage: "normalize",
        rule: StageRule::Exact,
        tasks: c.tgraph.tasks.clone(),
        events: c.tgraph.events.clone(),
    });
    report.violations.extend(check_stage_preservation(&chain));
    if opt.granularity != crate::tgraph::DepGranularity::Fine {
        if let Some(first) = snapshots.first() {
            report
                .violations
                .extend(check_ablation_superset(&c.graph, &c.decomposition, first));
        }
    }
    report.wall_us = t0.elapsed().as_micros() as u64;
    report
}

// ---------------------------------------------------------------------------
// Mutation testing — the verifier's own validation
// ---------------------------------------------------------------------------

/// What a seeded mutation did to the graph.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MutationKind {
    /// Removed an event→task launch edge (the task was re-attached to
    /// the start event, modeling a dropped dependency).
    DropDependency,
    /// Removed a task→event completion edge (the task's completion
    /// becomes invisible).
    DropTrigger,
    /// Re-pointed a task's dependency at an event that cannot restore
    /// the original ordering.
    RedirectDependency,
    /// Re-pointed a task's completion signal at an event that cannot
    /// restore the original ordering.
    RedirectTrigger,
}

/// A single applied edge mutation.
#[derive(Clone, Copy, Debug)]
pub struct Mutation {
    pub kind: MutationKind,
    pub event: EventId,
    pub task: TaskId,
    /// Redirection target (None for drops).
    pub new_event: Option<EventId>,
}

impl std::fmt::Display for Mutation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self.new_event {
            Some(ne) => write!(
                f,
                "{:?} task {} edge: event {} -> event {}",
                self.kind, self.task, self.event, ne
            ),
            None => write!(f, "{:?} task {} / event {}", self.kind, self.task, self.event),
        }
    }
}

/// Events reachable from `e` (inclusive) over the event graph.
fn event_descendants(tg: &TGraph, e: EventId) -> Vec<bool> {
    let mut seen = vec![false; tg.events.len()];
    let mut stack = vec![e];
    seen[e] = true;
    while let Some(cur) = stack.pop() {
        for &t in &tg.events[cur].out_tasks {
            for &ne in &tg.tasks[t].trigger_events {
                if !seen[ne] {
                    seen[ne] = true;
                    stack.push(ne);
                }
            }
        }
    }
    seen
}

/// Events that reach `e` (inclusive) over the event graph.
fn event_ancestors(tg: &TGraph, e: EventId) -> Vec<bool> {
    let mut seen = vec![false; tg.events.len()];
    let mut stack = vec![e];
    seen[e] = true;
    while let Some(cur) = stack.pop() {
        for &t in &tg.events[cur].in_tasks {
            for &pe in &tg.tasks[t].dependent_events {
                if !seen[pe] {
                    seen[pe] = true;
                    stack.push(pe);
                }
            }
        }
    }
    seen
}

/// Apply one seeded single-edge mutation to a copy of `tg`, keeping the
/// result structurally consistent (`check_consistent` still passes) so
/// it models a plausible *compiler* bug rather than corrupted memory.
/// Returns `None` when the graph has no eligible edge.
pub fn mutate(tg: &TGraph, seed: u64) -> Option<(TGraph, Mutation)> {
    let mut rng = XorShift64::new(seed);
    // dependency edges that encode a real ordering (not start-attach),
    // and completion edges.
    let dep_edges: Vec<(EventId, TaskId)> = tg
        .events
        .iter()
        .filter(|e| e.id != tg.start_event)
        .flat_map(|e| e.out_tasks.iter().map(move |&t| (e.id, t)))
        .collect();
    let trig_edges: Vec<(TaskId, EventId)> = tg
        .events
        .iter()
        .filter(|e| e.id != tg.start_event)
        .flat_map(|e| e.in_tasks.iter().map(move |&t| (t, e.id)))
        .collect();
    if dep_edges.is_empty() && trig_edges.is_empty() {
        return None;
    }

    for _attempt in 0..8 {
        let kind = match rng.below(4) {
            0 => MutationKind::DropDependency,
            1 => MutationKind::DropTrigger,
            2 => MutationKind::RedirectDependency,
            _ => MutationKind::RedirectTrigger,
        };
        let mut g = tg.clone();
        match kind {
            MutationKind::DropDependency | MutationKind::RedirectDependency => {
                if dep_edges.is_empty() {
                    continue;
                }
                let (e, t) = dep_edges[rng.below(dep_edges.len())];
                let new_event = if kind == MutationKind::RedirectDependency {
                    // any event that cannot re-establish the ordering:
                    // a non-descendant of `e` (start is always eligible).
                    let desc = event_descendants(tg, e);
                    let cands: Vec<EventId> =
                        (0..tg.events.len()).filter(|&x| !desc[x]).collect();
                    if cands.is_empty() {
                        Some(tg.start_event)
                    } else {
                        Some(cands[rng.below(cands.len())])
                    }
                } else {
                    None
                };
                g.events[e].out_tasks.retain(|&x| x != t);
                g.tasks[t].dependent_events.retain(|&x| x != e);
                let target = new_event.unwrap_or(tg.start_event);
                match kind {
                    MutationKind::RedirectDependency => {
                        g.tasks[t].dependent_events.push(target);
                        g.events[target].out_tasks.push(t);
                    }
                    _ => {
                        // a dropped dependency leaves the task parentless:
                        // the buggy compiler would attach it to start.
                        if g.tasks[t].dependent_events.is_empty() {
                            g.tasks[t].dependent_events.push(tg.start_event);
                            g.events[tg.start_event].out_tasks.push(t);
                        }
                    }
                }
                return Some((g, Mutation { kind, event: e, task: t, new_event }));
            }
            MutationKind::DropTrigger | MutationKind::RedirectTrigger => {
                if trig_edges.is_empty() {
                    continue;
                }
                let (t, e) = trig_edges[rng.below(trig_edges.len())];
                let new_event = if kind == MutationKind::RedirectTrigger {
                    // any non-ancestor of `e` except start (a trigger
                    // can't point at the start event).
                    let anc = event_ancestors(tg, e);
                    let cands: Vec<EventId> = (0..tg.events.len())
                        .filter(|&x| !anc[x] && x != tg.start_event)
                        .collect();
                    if cands.is_empty() {
                        continue;
                    }
                    Some(cands[rng.below(cands.len())])
                } else {
                    None
                };
                g.events[e].in_tasks.retain(|&x| x != t);
                g.tasks[t].trigger_events.retain(|&x| x != e);
                if let Some(ne) = new_event {
                    g.tasks[t].trigger_events.push(ne);
                    g.events[ne].in_tasks.push(t);
                }
                return Some((g, Mutation { kind, event: e, task: t, new_event }));
            }
        }
    }
    None
}

/// Outcome of a mutation sweep.
pub struct MutationSweep {
    pub total: usize,
    pub caught: usize,
    /// Mutations the race + liveness analyses failed to flag.
    pub survivors: Vec<Mutation>,
}

impl MutationSweep {
    pub fn catch_rate(&self) -> f64 {
        self.caught as f64 / self.total.max(1) as f64
    }
}

/// Run `n` seeded single-edge mutations against a known-good compiled
/// graph and count how many the race or liveness analysis catches.
pub fn mutation_sweep(c: &crate::tgraph::CompiledGraph, n: usize, seed: u64) -> MutationSweep {
    let mut sweep = MutationSweep { total: 0, caught: 0, survivors: Vec::new() };
    for i in 0..n {
        let Some((mutated, m)) =
            mutate(&c.tgraph, seed.wrapping_add(i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15))
        else {
            continue;
        };
        debug_assert_eq!(mutated.check_consistent(), Ok(()), "mutation broke consistency: {m}");
        sweep.total += 1;
        let report = verify_graph(&c.graph, &mutated);
        if report.is_clean() {
            sweep.survivors.push(m);
        } else {
            sweep.caught += 1;
        }
    }
    sweep
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{build_decode_graph, GraphOptions, ModelConfig};
    use crate::ops::{DType, LaunchMode, OpKind};
    use crate::tgraph::compiler::StageStats;
    use crate::tgraph::{compile, CompileOptions, DecomposeConfig};

    fn compile_tiny() -> crate::tgraph::CompiledGraph {
        let cfg = ModelConfig::tiny();
        let g = build_decode_graph(
            &cfg,
            &GraphOptions { batch: 2, kv_len: 16, ..Default::default() },
        );
        compile(
            &g,
            &CompileOptions {
                decompose: DecomposeConfig { target_tasks: 16, min_tile_cols: 8 },
                ..Default::default()
            },
        )
    }

    fn two_op_graph() -> CompGraph {
        let mut g = CompGraph::new();
        let x = g.input("x", vec![2, 16], DType::F32);
        let w = g.param("w", vec![16, 8], DType::F32);
        let y = g.op("mm", OpKind::MatMul, &[x, w], vec![2, 8], DType::F32);
        g.op("add", OpKind::Add, &[y, y], vec![2, 8], DType::F32);
        g
    }

    /// Hand-build a tGraph for `two_op_graph` where the Add task does
    /// NOT wait for the MatMul task — a racy graph the compiler must
    /// never emit.
    fn racy_tgraph(g: &CompGraph) -> TGraph {
        let mk = |id: usize, op: usize, kind: OpKind, dep: usize, trig: usize| TaskDesc {
            id,
            kind: TaskKind::Compute { op, kind },
            out_region: Region::full(&g.tensor(g.ops[op].output).shape),
            launch: LaunchMode::Aot,
            dependent_events: vec![dep],
            trigger_events: vec![trig],
            device: 0,
        };
        // both tasks launched straight from start: no ordering.
        let tasks = vec![
            mk(0, 0, OpKind::MatMul, 0, 1),
            mk(1, 1, OpKind::Add, 0, 1),
        ];
        let events = vec![
            EventDesc { id: 0, in_tasks: vec![], out_tasks: vec![0, 1] },
            EventDesc { id: 1, in_tasks: vec![0, 1], out_tasks: vec![] },
        ];
        TGraph { tasks, events, start_event: 0, end_event: 1, stats: StageStats::default() }
    }

    #[test]
    fn detects_missing_writer_reader_edge() {
        let g = two_op_graph();
        let tg = racy_tgraph(&g);
        let races = check_races(&g, &tg.tasks, &tg.events);
        assert!(
            races
                .violations
                .iter()
                .any(|v| matches!(v, Violation::Race { kind: RaceKind::WriteRead, .. })),
            "expected a write/read race, got {:?}",
            races.violations
        );
    }

    #[test]
    fn ordered_graph_is_race_free() {
        let g = two_op_graph();
        let mut tg = racy_tgraph(&g);
        // insert the missing edge: mm -> e2 -> add.
        tg.events.push(EventDesc { id: 2, in_tasks: vec![0], out_tasks: vec![1] });
        tg.tasks[0].trigger_events = vec![2];
        tg.tasks[1].dependent_events = vec![2];
        tg.events[0].out_tasks = vec![0];
        tg.events[1].in_tasks = vec![1];
        tg.check_consistent().unwrap();
        let races = check_races(&g, &tg.tasks, &tg.events);
        assert!(races.violations.is_empty(), "{:?}", races.violations);
        assert!(races.region_pairs > 0);
    }

    #[test]
    fn detects_cycle_as_deadlock() {
        let g = two_op_graph();
        let mut tg = racy_tgraph(&g);
        // t0 -> e2 -> t1 -> e3 -> t0: a cycle.
        tg.events.push(EventDesc { id: 2, in_tasks: vec![0], out_tasks: vec![1] });
        tg.events.push(EventDesc { id: 3, in_tasks: vec![1], out_tasks: vec![0] });
        tg.tasks[0].trigger_events = vec![2];
        tg.tasks[0].dependent_events = vec![0, 3];
        tg.tasks[1].dependent_events = vec![2];
        tg.tasks[1].trigger_events = vec![3];
        tg.events[0].out_tasks = vec![0];
        tg.events[1].in_tasks = vec![];
        let races = check_races(&g, &tg.tasks, &tg.events);
        assert!(races.violations.iter().any(|v| matches!(v, Violation::Deadlock { .. })));
        let live = check_liveness(&tg);
        assert!(!live.is_empty());
    }

    #[test]
    fn detects_unsatisfiable_event() {
        let g = two_op_graph();
        let mut tg = racy_tgraph(&g);
        // event 2 launches task 1 but nothing ever triggers it.
        tg.events.push(EventDesc { id: 2, in_tasks: vec![], out_tasks: vec![1] });
        tg.tasks[1].dependent_events = vec![2];
        tg.events[0].out_tasks = vec![0];
        let live = check_liveness(&tg);
        assert!(
            live.iter().any(|v| matches!(v, Violation::Deadlock { .. })),
            "expected deadlock, got {live:?}"
        );
    }

    #[test]
    fn detects_task_invisible_to_end_event() {
        let g = two_op_graph();
        let mut tg = racy_tgraph(&g);
        // task 1 triggers nothing: quiescence fires while it may still run.
        tg.tasks[1].trigger_events.clear();
        tg.events[1].in_tasks = vec![0];
        let live = check_liveness(&tg);
        assert!(
            live.iter().any(|v| matches!(v, Violation::Liveness { .. })),
            "expected liveness violation, got {live:?}"
        );
    }

    #[test]
    fn compiled_tiny_model_verifies_clean() {
        let c = compile_tiny();
        let report = verify_compiled(&c);
        assert!(report.is_clean(), "{}", report.render(8));
        assert!(report.region_pairs > 0);
        assert!(report.hb_edges > 0);
    }

    #[test]
    fn hb_closure_matches_hand_graph() {
        // chain t0 -> t1 -> t2 plus parallel t3.
        let mk = |id: usize, dep: &[usize], trig: &[usize]| TaskDesc {
            id,
            kind: TaskKind::Dummy,
            out_region: Region::new(vec![]),
            launch: LaunchMode::Aot,
            dependent_events: dep.to_vec(),
            trigger_events: trig.to_vec(),
            device: 0,
        };
        let tasks = vec![
            mk(0, &[0], &[1]),
            mk(1, &[1], &[2]),
            mk(2, &[2], &[3]),
            mk(3, &[0], &[3]),
        ];
        let events = vec![
            EventDesc { id: 0, in_tasks: vec![], out_tasks: vec![0, 3] },
            EventDesc { id: 1, in_tasks: vec![0], out_tasks: vec![1] },
            EventDesc { id: 2, in_tasks: vec![1], out_tasks: vec![2] },
            EventDesc { id: 3, in_tasks: vec![2, 3], out_tasks: vec![] },
        ];
        let cl = hb_closure(&tasks, &events, 4).unwrap();
        assert!(cl.ordered(0, 1) && cl.ordered(0, 2) && cl.ordered(1, 2));
        assert!(!cl.ordered(1, 0) && !cl.ordered(2, 0));
        assert!(!cl.ordered(0, 3) && !cl.ordered(3, 0) && !cl.ordered(3, 2));
    }

    #[test]
    fn mutations_on_tiny_model_are_caught() {
        let c = compile_tiny();
        let sweep = mutation_sweep(&c, 60, 0xFACADE);
        assert!(sweep.total >= 50, "mutator produced only {} mutations", sweep.total);
        assert!(
            sweep.catch_rate() >= 0.95,
            "catch rate {:.2} ({} of {}; survivors: {})",
            sweep.catch_rate(),
            sweep.caught,
            sweep.total,
            sweep
                .survivors
                .iter()
                .map(|m| m.to_string())
                .collect::<Vec<_>>()
                .join("; ")
        );
    }

    #[test]
    fn mutated_graphs_stay_structurally_consistent() {
        let c = compile_tiny();
        for i in 0..40u64 {
            if let Some((g, m)) = mutate(&c.tgraph, 0xBAD5EED + i) {
                assert_eq!(g.check_consistent(), Ok(()), "mutation {m} broke consistency");
            }
        }
    }
}
