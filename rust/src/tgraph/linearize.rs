//! tGraph linearization (§4.1, Algorithm 1).
//!
//! Orders tasks so that all tasks launched by the same event occupy a
//! contiguous index range; each event's fan-out is then encoded as just
//! `(first, last)` task indices instead of an explicit list, shrinking
//! the on-device footprint 4–15× (Table 2, "Lin." column).
//!
//! Requires a *normalized* graph where every task has exactly one
//! dependent event (the compiler attaches parentless tasks to the start
//! event before calling this).

use crate::tgraph::task::{EventDesc, TaskDesc, TaskId};

/// The linearized, runtime-ready encoding.
#[derive(Clone, Debug)]
pub struct LinearTGraph {
    /// Task ids in launch order (Algorithm 1 output list `T`).
    pub order: Vec<TaskId>,
    /// Inverse of `order`: position of each task.
    pub pos: Vec<usize>,
    /// Per event: `(first, last)` positions in `order` of the tasks it
    /// launches — inclusive — or `None` if the event launches nothing.
    pub event_range: Vec<Option<(usize, usize)>>,
    /// Per event: notifications required for activation.
    pub required: Vec<usize>,
}

impl LinearTGraph {
    /// Footprint in bytes of the successor encoding *with* linearization:
    /// first + last index (4 bytes each) per event.
    pub fn footprint_bytes(&self) -> usize {
        self.event_range.len() * 8
    }
}

/// Footprint without linearization: one 4-byte task index per (event,
/// successor task) entry.
pub fn naive_footprint_bytes(events: &[EventDesc]) -> usize {
    events.iter().map(|e| e.out_tasks.len() * 4).sum()
}

/// Algorithm 1. Panics on malformed input (task with ≠1 dependent event,
/// unreachable tasks, or a cyclic graph).
pub fn linearize(tasks: &[TaskDesc], events: &[EventDesc]) -> LinearTGraph {
    let n = tasks.len();
    for t in tasks {
        assert_eq!(
            t.dependent_events.len(),
            1,
            "linearize requires exactly one dependent event per task (task {})",
            t.id
        );
        assert!(t.trigger_events.len() <= 1, "task {} has >1 trigger events", t.id);
    }

    // tasks grouped by their (single) dependent event, ascending id for
    // determinism.
    let mut by_event: Vec<Vec<TaskId>> = vec![Vec::new(); events.len()];
    for t in tasks {
        by_event[t.dependent_events[0]].push(t.id);
    }
    for v in by_event.iter_mut() {
        v.sort_unstable();
    }

    let mut remaining: Vec<usize> = events.iter().map(|e| e.in_tasks.len()).collect();
    let mut queue: std::collections::VecDeque<usize> = (0..events.len())
        .filter(|&e| remaining[e] == 0)
        .collect();

    let mut order: Vec<TaskId> = Vec::with_capacity(n);
    let mut event_range: Vec<Option<(usize, usize)>> = vec![None; events.len()];
    let mut seen_event = vec![false; events.len()];
    for &e in queue.iter() {
        seen_event[e] = true;
    }

    while let Some(e) = queue.pop_front() {
        let launched = &by_event[e];
        if !launched.is_empty() {
            let first = order.len();
            for &t in launched {
                order.push(t);
                // lines 8-10: t's trigger event gains one placed trigger.
                if let Some(&ep) = tasks[t].trigger_events.first() {
                    remaining[ep] -= 1;
                    if remaining[ep] == 0 {
                        assert!(!seen_event[ep], "event {ep} enqueued twice");
                        seen_event[ep] = true;
                        queue.push_back(ep);
                    }
                }
            }
            event_range[e] = Some((first, order.len() - 1));
        }
    }
    assert_eq!(order.len(), n, "linearization left {} tasks unplaced (cycle or unreachable)", n - order.len());

    let mut pos = vec![0usize; n];
    for (i, &t) in order.iter().enumerate() {
        pos[t] = i;
    }
    let required = events.iter().map(|e| e.in_tasks.len()).collect();
    LinearTGraph { order, pos, event_range, required }
}

/// Check the central linearization invariant: for every event, the tasks
/// it launches are exactly the contiguous range recorded for it.
pub fn verify(lin: &LinearTGraph, tasks: &[TaskDesc], events: &[EventDesc]) -> Result<(), String> {
    for e in events {
        let launched: Vec<TaskId> = e.out_tasks.clone();
        match lin.event_range[e.id] {
            None => {
                if !launched.is_empty() {
                    return Err(format!("event {} launches tasks but has no range", e.id));
                }
            }
            Some((f, l)) => {
                if l + 1 - f != launched.len() {
                    return Err(format!("event {} range size mismatch", e.id));
                }
                for &t in &launched {
                    let p = lin.pos[t];
                    if p < f || p > l {
                        return Err(format!("task {t} outside event {} range", e.id));
                    }
                }
            }
        }
    }
    // order is a permutation
    let mut sorted = lin.order.clone();
    sorted.sort_unstable();
    if sorted != (0..tasks.len()).collect::<Vec<_>>() {
        return Err("order is not a permutation of tasks".into());
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::{LaunchMode, Region};
    use crate::tgraph::task::TaskKind;

    fn mk(id: usize, dep: usize, trig: Option<usize>) -> TaskDesc {
        TaskDesc {
            id,
            kind: TaskKind::Dummy,
            out_region: Region::new(vec![]),
            launch: LaunchMode::Aot,
            dependent_events: vec![dep],
            trigger_events: trig.into_iter().collect(),
            device: 0,
        }
    }

    #[test]
    fn chain_linearizes_in_order() {
        // e0(start) -> t0 -> e1 -> t1 -> e2 -> t2
        let tasks = vec![mk(0, 0, Some(1)), mk(1, 1, Some(2)), mk(2, 2, None)];
        let events = vec![
            EventDesc { id: 0, in_tasks: vec![], out_tasks: vec![0] },
            EventDesc { id: 1, in_tasks: vec![0], out_tasks: vec![1] },
            EventDesc { id: 2, in_tasks: vec![1], out_tasks: vec![2] },
        ];
        let lin = linearize(&tasks, &events);
        assert_eq!(lin.order, vec![0, 1, 2]);
        verify(&lin, &tasks, &events).unwrap();
    }

    #[test]
    fn fanout_tasks_contiguous() {
        // start launches t0; t0 -> e1 which launches t1..t3; all trigger e2.
        let tasks = vec![
            mk(0, 0, Some(1)),
            mk(1, 1, Some(2)),
            mk(2, 1, Some(2)),
            mk(3, 1, Some(2)),
        ];
        let events = vec![
            EventDesc { id: 0, in_tasks: vec![], out_tasks: vec![0] },
            EventDesc { id: 1, in_tasks: vec![0], out_tasks: vec![1, 2, 3] },
            EventDesc { id: 2, in_tasks: vec![1, 2, 3], out_tasks: vec![] },
        ];
        let lin = linearize(&tasks, &events);
        assert_eq!(lin.event_range[1], Some((1, 3)));
        assert_eq!(lin.required[2], 3);
        verify(&lin, &tasks, &events).unwrap();
    }

    #[test]
    fn footprint_shrinks_for_high_fanout() {
        // one event launching 100 tasks: naive = 400B, linear = 8B/event.
        let mut tasks = vec![mk(0, 0, Some(1))];
        let mut out = Vec::new();
        for i in 1..=100 {
            tasks.push(mk(i, 1, None));
            out.push(i);
        }
        let events = vec![
            EventDesc { id: 0, in_tasks: vec![], out_tasks: vec![0] },
            EventDesc { id: 1, in_tasks: vec![0], out_tasks: out },
        ];
        let lin = linearize(&tasks, &events);
        assert_eq!(naive_footprint_bytes(&events), 4 + 400);
        assert_eq!(lin.footprint_bytes(), 16);
        verify(&lin, &tasks, &events).unwrap();
    }

    #[test]
    #[should_panic(expected = "unplaced")]
    fn cycle_detected() {
        // t0 depends on e0 whose trigger is t0 itself (cycle).
        let tasks = vec![mk(0, 0, Some(0))];
        let events = vec![EventDesc { id: 0, in_tasks: vec![0], out_tasks: vec![0] }];
        linearize(&tasks, &events);
    }
}
