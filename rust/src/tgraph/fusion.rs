//! Event fusion (§4.1, Definitions 4.1 and 4.2).
//!
//! Dependency analysis emits one event per overlapping producer/consumer
//! task pair. Fusion collapses events with identical consumer sets
//! (*successor-set fusion*) or identical producer sets (*predecessor-set
//! fusion*) until fixpoint, cutting the number of synchronization points
//! by 1–2 orders of magnitude (Table 2 reports 37–118×) while preserving
//! every pairwise dependency.

use crate::tgraph::task::{EventDesc, TaskDesc};
use std::collections::HashMap;

/// Apply successor-set and predecessor-set fusion until fixpoint, then
/// rebuild the task↔event edge lists. Returns the fused event list.
pub fn fuse_events(tasks: &mut Vec<TaskDesc>, events: Vec<EventDesc>) -> Vec<EventDesc> {
    let mut evs: Vec<EventDesc> = events;
    loop {
        let before = evs.len();
        evs = fuse_by(evs, FuseMode::SuccessorSet);
        evs = fuse_by(evs, FuseMode::PredecessorSet);
        if evs.len() == before {
            break;
        }
    }
    // renumber and rebuild edges.
    for (i, e) in evs.iter_mut().enumerate() {
        e.id = i;
    }
    for t in tasks.iter_mut() {
        t.dependent_events.clear();
        t.trigger_events.clear();
    }
    for e in &evs {
        for &t in &e.in_tasks {
            tasks[t].trigger_events.push(e.id);
        }
        for &t in &e.out_tasks {
            tasks[t].dependent_events.push(e.id);
        }
    }
    evs
}

#[derive(Clone, Copy, PartialEq)]
enum FuseMode {
    /// Definition 4.1: merge events with equal `OutTasks`.
    SuccessorSet,
    /// Definition 4.2: merge events with equal `InTasks`.
    PredecessorSet,
}

fn fuse_by(events: Vec<EventDesc>, mode: FuseMode) -> Vec<EventDesc> {
    let mut groups: HashMap<Vec<usize>, EventDesc> = HashMap::new();
    let mut order: Vec<Vec<usize>> = Vec::new();
    for mut e in events {
        e.in_tasks.sort_unstable();
        e.in_tasks.dedup();
        e.out_tasks.sort_unstable();
        e.out_tasks.dedup();
        let key = match mode {
            FuseMode::SuccessorSet => e.out_tasks.clone(),
            FuseMode::PredecessorSet => e.in_tasks.clone(),
        };
        match groups.get_mut(&key) {
            None => {
                order.push(key.clone());
                groups.insert(key, e);
            }
            Some(acc) => match mode {
                FuseMode::SuccessorSet => {
                    acc.in_tasks.extend_from_slice(&e.in_tasks);
                    acc.in_tasks.sort_unstable();
                    acc.in_tasks.dedup();
                }
                FuseMode::PredecessorSet => {
                    acc.out_tasks.extend_from_slice(&e.out_tasks);
                    acc.out_tasks.sort_unstable();
                    acc.out_tasks.dedup();
                }
            },
        }
    }
    // deterministic output order: first-seen group order.
    order
        .into_iter()
        .map(|k| groups.remove(&k).expect("group present"))
        .collect()
}

/// Fork elimination: merge the trigger events of any task that has more
/// than one, and the dependent events of any task that has more than
/// one, until fixpoint.
///
/// This mirrors the paper's observation (§6.7) that production graphs
/// contain no fork/join groups because "operators that would otherwise
/// fan out are emitted as fused operators": a residual add that would
/// fork a matmul task's completion signal instead shares the matmul's
/// single synchronization point. Merging only *adds* ordering
/// constraints (unions of in/out sets), so it is always sound; the
/// Figure-6 dummy-task rewrite remains available for graphs where the
/// finer concurrency matters (`CompileOptions::merge_forks = false`).
pub fn merge_task_forks(tasks: &mut Vec<TaskDesc>, events: Vec<EventDesc>) -> Vec<EventDesc> {
    let mut evs = events;
    rebuild_edges(tasks, &mut evs);
    // Topological level per task (over the current DAG). A merge is
    // sound iff the merged event keeps `max level(in) < min level(out)`:
    // every edge still strictly increases level, so no cycle can form.
    let levels = task_levels(tasks, &evs);
    let ev_lo = |e: &EventDesc| e.out_tasks.iter().map(|&t| levels[t]).min().unwrap_or(usize::MAX);
    let ev_hi = |e: &EventDesc| e.in_tasks.iter().map(|&t| levels[t]).max().unwrap_or(0);
    loop {
        let mut changed = false;
        let merge_list = |lists: Vec<Vec<usize>>, evs: &mut Vec<EventDesc>, changed: &mut bool| {
            for list in lists {
                if list.len() <= 1 {
                    continue;
                }
                // greedy: fold events into the first while the level
                // invariant holds for the running union.
                let e0 = list[0];
                let mut hi = ev_hi(&evs[e0]);
                let mut lo = ev_lo(&evs[e0]);
                for &e in &list[1..] {
                    if e == e0 || (evs[e].in_tasks.is_empty() && evs[e].out_tasks.is_empty()) {
                        continue;
                    }
                    let nhi = hi.max(ev_hi(&evs[e]));
                    let nlo = lo.min(ev_lo(&evs[e]));
                    if nhi >= nlo {
                        continue; // would risk a cycle: keep the fork
                    }
                    hi = nhi;
                    lo = nlo;
                    let (ins, outs) = {
                        let ev = &mut evs[e];
                        (std::mem::take(&mut ev.in_tasks), std::mem::take(&mut ev.out_tasks))
                    };
                    evs[e0].in_tasks.extend(ins);
                    evs[e0].out_tasks.extend(outs);
                    *changed = true;
                }
                evs[e0].in_tasks.sort_unstable();
                evs[e0].in_tasks.dedup();
                evs[e0].out_tasks.sort_unstable();
                evs[e0].out_tasks.dedup();
            }
        };
        let trig: Vec<Vec<usize>> =
            tasks.iter().filter(|t| t.trigger_events.len() > 1).map(|t| t.trigger_events.clone()).collect();
        merge_list(trig, &mut evs, &mut changed);
        rebuild_edges(tasks, &mut evs);
        let deps: Vec<Vec<usize>> = tasks
            .iter()
            .filter(|t| t.dependent_events.len() > 1)
            .map(|t| t.dependent_events.clone())
            .collect();
        merge_list(deps, &mut evs, &mut changed);
        rebuild_edges(tasks, &mut evs);
        if !changed {
            break;
        }
    }
    // drop emptied tombstones, renumber, rebuild.
    let mut evs: Vec<EventDesc> =
        evs.into_iter().filter(|e| !(e.in_tasks.is_empty() && e.out_tasks.is_empty())).collect();
    for (i, e) in evs.iter_mut().enumerate() {
        e.id = i;
    }
    rebuild_edges(tasks, &mut evs);
    evs
}

/// Longest-path topological level of every task over the task/event DAG.
fn task_levels(tasks: &[TaskDesc], events: &[EventDesc]) -> Vec<usize> {
    let n = tasks.len();
    let mut level = vec![0usize; n];
    let mut indeg = vec![0usize; n];
    for t in tasks {
        indeg[t.id] = t.dependent_events.iter().map(|&e| events[e].in_tasks.len()).sum();
    }
    let mut queue: std::collections::VecDeque<usize> =
        (0..n).filter(|&t| indeg[t] == 0).collect();
    let mut seen = 0;
    while let Some(t) = queue.pop_front() {
        seen += 1;
        for &e in &tasks[t].trigger_events {
            for &succ in &events[e].out_tasks {
                level[succ] = level[succ].max(level[t] + 1);
                indeg[succ] -= 1;
                if indeg[succ] == 0 {
                    queue.push_back(succ);
                }
            }
        }
    }
    assert_eq!(seen, n, "task graph has a cycle before fork merging");
    level
}

/// Recompute every task's dependent/trigger lists from the event list
/// (events with stale ids are renumbered by position).
fn rebuild_edges(tasks: &mut [TaskDesc], events: &mut [EventDesc]) {
    for (i, e) in events.iter_mut().enumerate() {
        e.id = i;
    }
    for t in tasks.iter_mut() {
        t.dependent_events.clear();
        t.trigger_events.clear();
    }
    for e in events.iter() {
        for &t in &e.in_tasks {
            tasks[t].trigger_events.push(e.id);
        }
        for &t in &e.out_tasks {
            tasks[t].dependent_events.push(e.id);
        }
    }
}

/// The set of (producer, consumer) ordered pairs an event list encodes:
/// every (i, o) with i ∈ in_tasks, o ∈ out_tasks. Fusion must never
/// shrink this set (it may grow it — added synchronization is safe).
pub fn encoded_pairs(events: &[EventDesc]) -> std::collections::HashSet<(usize, usize)> {
    let mut s = std::collections::HashSet::new();
    for e in events {
        for &i in &e.in_tasks {
            for &o in &e.out_tasks {
                s.insert((i, o));
            }
        }
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::{LaunchMode, Region};
    use crate::tgraph::task::TaskKind;

    fn mk_tasks(n: usize) -> Vec<TaskDesc> {
        (0..n)
            .map(|id| TaskDesc {
                id,
                kind: TaskKind::Dummy,
                out_region: Region::new(vec![]),
                launch: LaunchMode::Aot,
                dependent_events: Vec::new(),
                trigger_events: Vec::new(),
                device: 0,
            })
            .collect()
    }

    fn ev(id: usize, ins: &[usize], outs: &[usize]) -> EventDesc {
        EventDesc { id, in_tasks: ins.to_vec(), out_tasks: outs.to_vec() }
    }

    #[test]
    fn successor_set_fusion_merges_shared_consumers() {
        // e0: {0}->{2}, e1: {1}->{2}  — both prerequisites of task 2.
        let mut tasks = mk_tasks(3);
        let events = vec![ev(0, &[0], &[2]), ev(1, &[1], &[2])];
        let fused = fuse_events(&mut tasks, events);
        assert_eq!(fused.len(), 1);
        assert_eq!(fused[0].in_tasks, vec![0, 1]);
        assert_eq!(fused[0].out_tasks, vec![2]);
        assert_eq!(tasks[2].dependent_events.len(), 1);
    }

    #[test]
    fn predecessor_set_fusion_merges_shared_producers() {
        // e0: {0,1}->{2}, e1: {0,1}->{3} — triggered simultaneously.
        let mut tasks = mk_tasks(4);
        let events = vec![ev(0, &[0, 1], &[2]), ev(1, &[0, 1], &[3])];
        let fused = fuse_events(&mut tasks, events);
        assert_eq!(fused.len(), 1);
        assert_eq!(fused[0].out_tasks, vec![2, 3]);
    }

    #[test]
    fn fusion_preserves_dependency_pairs() {
        let mut tasks = mk_tasks(6);
        let events = vec![
            ev(0, &[0], &[3]),
            ev(1, &[1], &[3]),
            ev(2, &[0], &[4]),
            ev(3, &[1], &[4]),
            ev(4, &[2], &[5]),
        ];
        let before = encoded_pairs(&events);
        let fused = fuse_events(&mut tasks, events);
        let after = encoded_pairs(&fused);
        assert!(after.is_superset(&before));
        // {0,1}->{3} and {0,1}->{4} then merge into {0,1}->{3,4}.
        assert_eq!(fused.len(), 2);
    }

    #[test]
    fn one_to_one_chain_untouched() {
        let mut tasks = mk_tasks(4);
        let events = vec![ev(0, &[0], &[1]), ev(1, &[1], &[2]), ev(2, &[2], &[3])];
        let fused = fuse_events(&mut tasks, events);
        assert_eq!(fused.len(), 3);
    }

    #[test]
    fn duplicate_pair_events_collapse() {
        let mut tasks = mk_tasks(2);
        let events = vec![ev(0, &[0], &[1]), ev(1, &[0], &[1])];
        let fused = fuse_events(&mut tasks, events);
        assert_eq!(fused.len(), 1);
    }

    #[test]
    fn edges_rebuilt_consistently() {
        let mut tasks = mk_tasks(5);
        let events =
            vec![ev(0, &[0], &[2]), ev(1, &[1], &[2]), ev(2, &[2], &[3]), ev(3, &[2], &[4])];
        let fused = fuse_events(&mut tasks, events);
        for e in &fused {
            for &t in &e.in_tasks {
                assert!(tasks[t].trigger_events.contains(&e.id));
            }
            for &t in &e.out_tasks {
                assert!(tasks[t].dependent_events.contains(&e.id));
            }
        }
    }
}
