//! Task and event descriptors — the nodes of a *t*Graph (§3).
//!
//! Tasks and events alternate: a task has incoming edges only from its
//! *dependent* events and outgoing edges only to its *triggering* events.
//! Before normalization both lists may hold several events; after
//! normalization ([`crate::tgraph::normalize`]) each holds at most one,
//! which is what allows the fixed-size task descriptor the in-kernel
//! runtime consumes (the paper's 352-byte record, §5.3).

use crate::ops::{LaunchMode, OpKind, Region};

pub type TaskId = usize;
pub type EventId = usize;

/// What a task does when a worker dequeues it.
#[derive(Clone, Debug, PartialEq)]
pub enum TaskKind {
    /// Compute (or intra-op communication) tile of operator `op`.
    Compute { op: usize, kind: OpKind },
    /// Inter-GPU data transfer produced by collective lowering (§6.5):
    /// move `bytes` from `src_dev` to `dst_dev`.
    Transfer { op: usize, src_dev: usize, dst_dev: usize, bytes: u64 },
    /// Empty task inserted by tGraph normalization (Figure 6); performs
    /// no work, only propagates events.
    Dummy,
    /// The per-iteration bookkeeping task of §6.1: retire finished
    /// requests, admit new ones, update KV metadata.
    IterPrep,
}

impl TaskKind {
    pub fn is_dummy(&self) -> bool {
        matches!(self, TaskKind::Dummy)
    }

    pub fn is_comm(&self) -> bool {
        match self {
            TaskKind::Transfer { .. } => true,
            TaskKind::Compute { kind, .. } => kind.is_comm(),
            _ => false,
        }
    }
}

/// A unit of work executed on a single SM (worker thread).
#[derive(Clone, Debug)]
pub struct TaskDesc {
    pub id: TaskId,
    pub kind: TaskKind,
    /// Tile of the producing operator's output tensor (empty for dummies).
    pub out_region: Region,
    pub launch: LaunchMode,
    /// Events that must all be activated before this task may run.
    /// Normalization shrinks this to exactly one.
    pub dependent_events: Vec<EventId>,
    /// Events notified on completion. Normalization shrinks this to at
    /// most one (sink tasks trigger the graph's end event).
    pub trigger_events: Vec<EventId>,
    /// Device owning the task (tensor-parallel rank; 0 on single GPU).
    pub device: usize,
}

impl TaskDesc {
    pub fn op_id(&self) -> Option<usize> {
        match self.kind {
            TaskKind::Compute { op, .. } | TaskKind::Transfer { op, .. } => Some(op),
            _ => None,
        }
    }
}

/// A synchronization point: activated once all of `in_tasks` have
/// notified it; on activation, all of `out_tasks` become launchable.
#[derive(Clone, Debug, Default)]
pub struct EventDesc {
    pub id: EventId,
    pub in_tasks: Vec<TaskId>,
    pub out_tasks: Vec<TaskId>,
}

impl EventDesc {
    /// Number of notifications required for activation.
    pub fn required_triggers(&self) -> usize {
        self.in_tasks.len()
    }
}

/// The SM-level graph: tasks + events (§3), plus the designated start
/// event (no prerequisites) and end event (quiescence detection).
#[derive(Clone, Debug)]
pub struct TGraph {
    pub tasks: Vec<TaskDesc>,
    pub events: Vec<EventDesc>,
    pub start_event: EventId,
    pub end_event: EventId,
    /// Per-compiler-stage statistics (Table 2), filled by the pipeline.
    pub stats: super::compiler::StageStats,
}

/// First duplicated id in a list, if any.
fn first_dup(ids: &[usize]) -> Option<usize> {
    let mut sorted = ids.to_vec();
    sorted.sort_unstable();
    sorted.windows(2).find(|w| w[0] == w[1]).map(|w| w[0])
}

impl TGraph {
    /// Structural invariant check: edge lists are mutually consistent,
    /// ids in range, no list holds the same id twice (a duplicate
    /// in-task inflates an event's `required_triggers` beyond what can
    /// ever arrive and deadlocks the runtime; a duplicate out-task
    /// would launch a task twice), the start event has no in-tasks.
    pub fn check_consistent(&self) -> Result<(), String> {
        for t in &self.tasks {
            if let Some(e) = first_dup(&t.dependent_events) {
                return Err(format!("task {} lists dependent event {e} twice", t.id));
            }
            if let Some(e) = first_dup(&t.trigger_events) {
                return Err(format!("task {} lists trigger event {e} twice", t.id));
            }
            for &e in t.dependent_events.iter() {
                if e >= self.events.len() {
                    return Err(format!("task {} dependent event {e} out of range", t.id));
                }
                if !self.events[e].out_tasks.contains(&t.id) {
                    return Err(format!("task {} missing from event {e} out_tasks", t.id));
                }
            }
            for &e in t.trigger_events.iter() {
                if e >= self.events.len() {
                    return Err(format!("task {} trigger event {e} out of range", t.id));
                }
                if !self.events[e].in_tasks.contains(&t.id) {
                    return Err(format!("task {} missing from event {e} in_tasks", t.id));
                }
            }
        }
        for ev in &self.events {
            if let Some(t) = first_dup(&ev.in_tasks) {
                return Err(format!(
                    "event {} lists in-task {t} twice (required_triggers would never be met)",
                    ev.id
                ));
            }
            if let Some(t) = first_dup(&ev.out_tasks) {
                return Err(format!("event {} lists out-task {t} twice", ev.id));
            }
            for &t in ev.out_tasks.iter() {
                if !self.tasks[t].dependent_events.contains(&ev.id) {
                    return Err(format!("event {} missing from task {t} dependents", ev.id));
                }
            }
            for &t in ev.in_tasks.iter() {
                if !self.tasks[t].trigger_events.contains(&ev.id) {
                    return Err(format!("event {} missing from task {t} triggers", ev.id));
                }
            }
        }
        if !self.events[self.start_event].in_tasks.is_empty() {
            return Err("start event has in-tasks".into());
        }
        Ok(())
    }

    /// True iff every task has ≤1 dependent and ≤1 triggering event
    /// (the post-normalization property).
    pub fn is_normalized(&self) -> bool {
        self.tasks
            .iter()
            .all(|t| t.dependent_events.len() <= 1 && t.trigger_events.len() <= 1)
    }

    /// Number of non-dummy tasks.
    pub fn real_task_count(&self) -> usize {
        self.tasks.iter().filter(|t| !t.kind.is_dummy()).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::LaunchMode;

    fn mk_task(id: usize, deps: &[usize], trigs: &[usize]) -> TaskDesc {
        TaskDesc {
            id,
            kind: TaskKind::Dummy,
            out_region: Region::new(vec![]),
            launch: LaunchMode::Aot,
            dependent_events: deps.to_vec(),
            trigger_events: trigs.to_vec(),
            device: 0,
        }
    }

    /// start -> t0 -> e1 -> t1 -> end, fully consistent.
    fn chain() -> TGraph {
        TGraph {
            tasks: vec![mk_task(0, &[0], &[1]), mk_task(1, &[1], &[2])],
            events: vec![
                EventDesc { id: 0, in_tasks: vec![], out_tasks: vec![0] },
                EventDesc { id: 1, in_tasks: vec![0], out_tasks: vec![1] },
                EventDesc { id: 2, in_tasks: vec![1], out_tasks: vec![] },
            ],
            start_event: 0,
            end_event: 2,
            stats: Default::default(),
        }
    }

    #[test]
    fn consistent_chain_passes() {
        chain().check_consistent().unwrap();
    }

    #[test]
    fn duplicate_in_task_rejected() {
        // the duplicate would make required_triggers = 2 with only one
        // notifier: an unconditional runtime deadlock.
        let mut g = chain();
        g.events[2].in_tasks = vec![1, 1];
        g.tasks[1].trigger_events = vec![2];
        let err = g.check_consistent().unwrap_err();
        assert!(err.contains("in-task 1 twice"), "{err}");
    }

    #[test]
    fn duplicate_out_task_rejected() {
        let mut g = chain();
        g.events[1].out_tasks = vec![1, 1];
        let err = g.check_consistent().unwrap_err();
        assert!(err.contains("out-task 1 twice"), "{err}");
    }

    #[test]
    fn duplicate_dependent_event_rejected() {
        let mut g = chain();
        g.tasks[1].dependent_events = vec![1, 1];
        let err = g.check_consistent().unwrap_err();
        assert!(err.contains("dependent event 1 twice"), "{err}");
    }

    #[test]
    fn duplicate_trigger_event_rejected() {
        let mut g = chain();
        g.tasks[0].trigger_events = vec![1, 1];
        let err = g.check_consistent().unwrap_err();
        assert!(err.contains("trigger event 1 twice"), "{err}");
    }
}
