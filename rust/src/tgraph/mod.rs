//! The MPK compiler: computation graph → optimized SM-level tGraph (§4).
pub mod build;
pub mod compiler;
pub mod fusion;
pub mod linearize;
pub mod normalize;
pub mod task;

pub use build::{analyze_deps, decompose, DecomposeConfig, OpTasks};
pub use compiler::{compile, CompileOptions, CompiledGraph, DepGranularity, StageStats};
pub use linearize::{linearize, LinearTGraph};
pub use task::{EventDesc, EventId, TGraph, TaskDesc, TaskId, TaskKind};
