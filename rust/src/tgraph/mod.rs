//! The MPK compiler: computation graph → optimized SM-level tGraph (§4).
pub mod build;
pub mod compiler;
pub mod fusion;
pub mod linearize;
pub mod normalize;
pub mod task;
pub mod verify;

pub use build::{analyze_deps, decompose, DecomposeConfig, OpTasks};
pub use compiler::{
    compile, compile_verified, CompileOptions, CompiledGraph, DepGranularity, StageStats,
};
pub use linearize::{linearize, LinearTGraph};
pub use task::{EventDesc, EventId, TGraph, TaskDesc, TaskId, TaskKind};
pub use verify::{
    mutation_sweep, verify_compiled, verify_graph, Mutation, MutationKind, MutationSweep,
    VerifyReport, Violation,
};
