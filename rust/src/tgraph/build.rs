//! Operator decomposition and fine-grained dependency analysis (§4.1).
//!
//! Decomposition partitions each operator's *output* tensor into disjoint
//! tiles, one task per tile, choosing the partition that minimizes
//! modeled device-memory loads subject to producing roughly
//! `target_tasks` tasks (≈ the worker count, for load balance).
//! Dependency analysis then enumerates producer/consumer task pairs and
//! emits one event per pair whose regions overlap; event fusion (§4.1,
//! Definitions 4.1–4.2) later collapses these.

use crate::ops::{CompGraph, Op, OpKind, Region, TensorId};
use crate::tgraph::task::{EventDesc, TaskDesc, TaskKind};
use std::collections::HashMap;

/// Decomposition parameters.
#[derive(Clone, Copy, Debug)]
pub struct DecomposeConfig {
    /// Desired tasks per operator (≈ number of worker SMs).
    pub target_tasks: usize,
    /// Minimum tile width along the last output dimension, to keep tiles
    /// MXU/TMA friendly.
    pub min_tile_cols: usize,
}

impl Default for DecomposeConfig {
    fn default() -> Self {
        DecomposeConfig { target_tasks: 128, min_tile_cols: 8 }
    }
}

/// Tasks of one decomposed operator.
#[derive(Clone, Debug)]
pub struct OpTasks {
    pub op: usize,
    /// Parts per output dimension actually used.
    pub partition: Vec<usize>,
    /// Output tile per task, row-major over the partition grid.
    pub tiles: Vec<Region>,
}

/// Decompose every operator of `g` into tiles.
///
/// Elementwise consumers (Add, AllReduce) inherit the partition of a
/// same-shaped producer so that their tasks align 1:1 with the producer's
/// tiles — this is what creates the Figure-4 fine-grained MatMul→AllReduce
/// dependency structure.
pub fn decompose(g: &CompGraph, cfg: &DecomposeConfig) -> Vec<OpTasks> {
    let mut chosen: HashMap<usize, Vec<usize>> = HashMap::new();
    let mut out = Vec::with_capacity(g.ops.len());
    for &oid in g.topo_order().iter() {
        let op = &g.ops[oid];
        let shape = &g.tensor(op.output).shape;
        let partition = if let Some(h) = &op.partition_hint {
            h.clone()
        } else {
            choose_partition(g, op, shape, cfg, &chosen)
        };
        let partition: Vec<usize> = partition
            .iter()
            .zip(shape.iter())
            .map(|(&p, &s)| p.clamp(1, s.max(1)))
            .collect();
        let tiles = tiles_for(shape, &partition);
        chosen.insert(oid, partition.clone());
        out.push(OpTasks { op: oid, partition, tiles });
    }
    out.sort_by_key(|t| t.op);
    out
}

/// Cartesian tiling of `shape` by `parts` per dimension.
pub fn tiles_for(shape: &[usize], parts: &[usize]) -> Vec<Region> {
    let ranges: Vec<Vec<(usize, usize)>> = shape
        .iter()
        .zip(parts.iter())
        .map(|(&s, &p)| crate::ops::split_ranges(s, p))
        .collect();
    let mut tiles = vec![Region::new(vec![])];
    for dim_ranges in &ranges {
        let mut next = Vec::with_capacity(tiles.len() * dim_ranges.len());
        for t in &tiles {
            for &r in dim_ranges {
                let mut dims = t.dims.clone();
                dims.push(r);
                next.push(Region::new(dims));
            }
        }
        tiles = next;
    }
    tiles
}

fn choose_partition(
    g: &CompGraph,
    op: &Op,
    shape: &[usize],
    cfg: &DecomposeConfig,
    chosen: &HashMap<usize, Vec<usize>>,
) -> Vec<usize> {
    let target = cfg.target_tasks.max(1);
    match &op.kind {
        // Row-wise ops: one task per (group of) rows. At batch 1 this is
        // a single task, matching §6.7 ("normalization at batch size one
        // maps to a single task").
        OpKind::Embedding => vec![shape[0].min(target), 1],
        OpKind::RmsNorm | OpKind::KvAppend => {
            let mut p = vec![shape[0].min(target)];
            p.extend(std::iter::repeat(1).take(shape.len() - 1));
            p
        }
        OpKind::Attention { kv_heads, .. } => {
            // FlashDecoding-style split: one task per (request, kv-head
            // group) so batch-1 attention still spreads across SMs.
            let rows = shape[0].min(target);
            let groups = (*kv_heads).clamp(1, (target / rows.max(1)).max(1));
            vec![rows, groups]
        }
        OpKind::MatMul => choose_matmul_partition(g, op, shape, cfg),
        // Elementwise: inherit a same-shaped producer's partition for
        // 1:1 tile alignment; otherwise split columns.
        OpKind::Add | OpKind::AllReduce { .. } => {
            for &inp in &op.inputs {
                if let Some(pid) = g.producer[inp] {
                    if g.tensor(g.ops[pid].output).shape == shape {
                        if let Some(p) = chosen.get(&pid) {
                            return p.clone();
                        }
                    }
                }
            }
            default_2d(shape, target, cfg.min_tile_cols)
        }
        // SwiGLU reads both packed halves of its input; column tiles
        // would conservatively depend on every producer tile (all-pairs
        // blowup), so split by rows only.
        OpKind::SwiGLU => {
            let mut p = vec![shape[0].min(target)];
            p.extend(std::iter::repeat(1).take(shape.len() - 1));
            p
        }
        OpKind::MoeRoute { .. } => vec![1, 1],
        // Grouped expert GEMM: tasks ∝ workers (the runtime balancer
        // refines per-task token shares from the routing meta-tensor).
        OpKind::MoeExpertGemm { .. } => {
            let cols = (shape[1] / cfg.min_tile_cols.max(1)).max(1);
            vec![shape[0].min(target), cols.min((target / shape[0].max(1)).max(1)).min(32)]
        }
        OpKind::MoeCombine { .. } => vec![shape[0].min(target.min(8)), 1],
    }
}

fn default_2d(shape: &[usize], target: usize, min_cols: usize) -> Vec<usize> {
    if shape.len() == 1 {
        return vec![shape[0].min(target)];
    }
    let rows = shape[0];
    let cols = shape[shape.len() - 1];
    let pr = rows.min(target);
    let pc = ((target / pr.max(1)).max(1)).min((cols / min_cols.max(1)).max(1));
    let mut p = vec![pr];
    p.extend(std::iter::repeat(1).take(shape.len() - 2));
    p.push(pc);
    p
}

/// Pick the MatMul tiling minimizing modeled HBM loads: enumerate row
/// splits (powers of two up to B), derive the column split from the task
/// target, and score `Σ_tiles (rows·K + K·cols)·elem` (x re-loaded per
/// column tile, weight tiles disjoint — §4.1's "minimize data loading").
fn choose_matmul_partition(g: &CompGraph, op: &Op, shape: &[usize], cfg: &DecomposeConfig) -> Vec<usize> {
    let b = shape[0];
    let n = shape[1];
    let k = g.tensor(op.inputs[0]).shape[1];
    let elem = g.tensor(op.output).dtype.size();
    let target = cfg.target_tasks.max(1);
    let max_pn = (n / cfg.min_tile_cols.max(1)).max(1);

    // Task count stays ≈ target (load balance, §4.1: "a number of tasks
    // proportional to the number of SMs"); the byte search only chooses
    // the *shape* — how the ~target tasks split between rows and columns.
    let mut best: Option<(u64, Vec<usize>)> = None;
    let mut pb = 1usize;
    loop {
        let pn = target.div_ceil(pb).clamp(1, max_pn);
        let tiles_rows = crate::ops::split_ranges(b, pb);
        let tiles_cols = crate::ops::split_ranges(n, pn);
        let mut bytes: u64 = 0;
        for &(r0, r1) in &tiles_rows {
            for &(c0, c1) in &tiles_cols {
                bytes += (((r1 - r0) * k + k * (c1 - c0)) * elem) as u64;
            }
        }
        if best.as_ref().map_or(true, |(s, _)| bytes < *s) {
            best = Some((bytes, vec![pb, pn]));
        }
        if pb >= b {
            break;
        }
        pb = (pb * 2).min(b);
    }
    best.unwrap().1
}

/// Result of dependency analysis: the un-fused tGraph pieces.
pub struct RawTGraph {
    pub tasks: Vec<TaskDesc>,
    pub events: Vec<EventDesc>,
    /// op id → (first task id, count), tasks contiguous per op.
    pub op_task_span: Vec<(usize, usize)>,
    /// Total overlapping producer/consumer pairs found (Table 2 input).
    pub dep_pairs: usize,
}

/// Materialize tasks and emit one event per overlapping producer/consumer
/// task pair (§4.1 "Dependency analysis").
pub fn analyze_deps(g: &CompGraph, decomp: &[OpTasks]) -> RawTGraph {
    let mut tasks: Vec<TaskDesc> = Vec::new();
    let mut op_task_span = vec![(0usize, 0usize); g.ops.len()];
    for ot in decomp {
        let op = &g.ops[ot.op];
        let first = tasks.len();
        for tile in &ot.tiles {
            tasks.push(TaskDesc {
                id: tasks.len(),
                kind: TaskKind::Compute { op: op.id, kind: op.kind.clone() },
                out_region: tile.clone(),
                launch: op.launch(),
                dependent_events: Vec::new(),
                trigger_events: Vec::new(),
                device: 0,
            });
        }
        op_task_span[ot.op] = (first, ot.tiles.len());
    }

    // consumer walk: for each op input with a producer, pair up tiles.
    let mut events: Vec<EventDesc> = Vec::new();
    let mut dep_pairs = 0usize;
    let mut emit = |tasks: &mut [TaskDesc], events: &mut Vec<EventDesc>, pt: usize, ct: usize| {
        dep_pairs += 1;
        let eid = events.len();
        events.push(EventDesc { id: eid, in_tasks: vec![pt], out_tasks: vec![ct] });
        tasks[pt].trigger_events.push(eid);
        tasks[ct].dependent_events.push(eid);
    };
    for op in &g.ops {
        let (cfirst, ccount) = op_task_span[op.id];
        for (idx, &inp) in op.inputs.iter().enumerate() {
            let Some(pid) = producer_of(g, inp) else { continue };
            let (pfirst, pcount) = op_task_span[pid];
            let in_shape = &g.tensor(inp).shape;
            // perf fast path: elementwise consumers whose tiling matches
            // the producer 1:1 (Add/AllReduce inherit the producer's
            // partition) need no O(n²) overlap scan — tile i depends on
            // tile i exactly. (§Perf in EXPERIMENTS.md: ~2.5x faster
            // dependency analysis on the dense models.)
            let elementwise_identity = matches!(op.kind, OpKind::Add | OpKind::AllReduce { .. })
                && pcount == ccount
                && (0..ccount).all(|i| tasks[pfirst + i].out_region == tasks[cfirst + i].out_region);
            if elementwise_identity {
                for i in 0..ccount {
                    emit(&mut tasks, &mut events, pfirst + i, cfirst + i);
                }
                continue;
            }
            for ct in cfirst..cfirst + ccount {
                let need = op.kind.input_region(&tasks[ct].out_region, idx, in_shape);
                for pt in pfirst..pfirst + pcount {
                    if tasks[pt].out_region.overlaps(&need) {
                        emit(&mut tasks, &mut events, pt, ct);
                    }
                }
            }
        }
    }
    RawTGraph { tasks, events, op_task_span, dep_pairs }
}

fn producer_of(g: &CompGraph, t: TensorId) -> Option<usize> {
    g.producer[t]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::DType;

    fn mm_ar_graph(b: usize, n: usize) -> CompGraph {
        let mut g = CompGraph::new();
        let x = g.input("x", vec![b, 64], DType::BF16);
        let w = g.param("w", vec![64, n], DType::BF16);
        let y = g.op("mm", OpKind::MatMul, &[x, w], vec![b, n], DType::BF16);
        g.op("ar", OpKind::AllReduce { world: 4 }, &[y], vec![b, n], DType::BF16);
        g
    }

    #[test]
    fn tiles_partition_output_disjointly() {
        let tiles = tiles_for(&[4, 32], &[2, 4]);
        assert_eq!(tiles.len(), 8);
        let total: usize = tiles.iter().map(|t| t.numel()).sum();
        assert_eq!(total, 4 * 32);
        for i in 0..tiles.len() {
            for j in i + 1..tiles.len() {
                assert!(!tiles[i].overlaps(&tiles[j]), "tiles {i} and {j} overlap");
            }
        }
    }

    #[test]
    fn allreduce_inherits_matmul_partition() {
        let g = mm_ar_graph(2, 256);
        let d = decompose(&g, &DecomposeConfig { target_tasks: 16, min_tile_cols: 8 });
        assert_eq!(d[0].partition, d[1].partition, "AR should inherit MM tiling");
    }

    #[test]
    fn matmul_allreduce_deps_are_one_to_one() {
        let g = mm_ar_graph(2, 256);
        let d = decompose(&g, &DecomposeConfig { target_tasks: 16, min_tile_cols: 8 });
        let raw = analyze_deps(&g, &d);
        let (first, count) = raw.op_task_span[1];
        // each AllReduce task depends on exactly one MatMul task.
        for t in first..first + count {
            assert_eq!(raw.tasks[t].dependent_events.len(), 1, "AR task {t} deps");
        }
        assert_eq!(raw.dep_pairs, count);
    }

    #[test]
    fn matmul_task_count_near_target() {
        let g = mm_ar_graph(1, 4096);
        let d = decompose(&g, &DecomposeConfig { target_tasks: 128, min_tile_cols: 8 });
        let tasks = d[0].tiles.len();
        assert!((64..=256).contains(&tasks), "got {tasks} tasks");
    }

    #[test]
    fn dep_analysis_is_conservative_for_rowwise() {
        // RMSNorm reads the full row: a downstream matmul row tile must
        // depend on every producer tile covering that row.
        let mut g = CompGraph::new();
        let x = g.input("x", vec![4, 64], DType::F32);
        let nw = g.param("nw", vec![64], DType::F32);
        let n = g.op("rms", OpKind::RmsNorm, &[x, nw], vec![4, 64], DType::F32);
        let w = g.param("w", vec![64, 32], DType::F32);
        g.op("mm", OpKind::MatMul, &[n, w], vec![4, 32], DType::F32);
        let d = decompose(&g, &DecomposeConfig { target_tasks: 8, min_tile_cols: 8 });
        let raw = analyze_deps(&g, &d);
        // every matmul task has at least one dependency on rmsnorm.
        let (first, count) = raw.op_task_span[1];
        for t in first..first + count {
            assert!(!raw.tasks[t].dependent_events.is_empty());
        }
    }

    #[test]
    fn hint_overrides_choice() {
        let mut g = mm_ar_graph(2, 256);
        g.ops[0].partition_hint = Some(vec![1, 3]);
        let d = decompose(&g, &DecomposeConfig::default());
        assert_eq!(d[0].tiles.len(), 3);
    }
}
