//! The end-to-end MPK compiler pipeline (§4, Figure 5):
//!
//! computation graph → decompose → dependency analysis → event fusion →
//! JIT/AOT classification → normalization → start/end attachment →
//! linearization, with per-stage statistics (Table 2).

use crate::ops::{CompGraph, LaunchMode, Region};
use crate::tgraph::build::{analyze_deps, decompose, DecomposeConfig, OpTasks, RawTGraph};
use crate::tgraph::fusion::fuse_events;
use crate::tgraph::linearize::{linearize, naive_footprint_bytes, LinearTGraph};
use crate::tgraph::normalize::normalize;
use crate::tgraph::task::{EventDesc, EventId, TGraph, TaskDesc, TaskKind};
use crate::tgraph::verify::{StageRule, StageSnapshot, VerifyReport};

/// Dependency granularity, for the Figure 13 ablation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DepGranularity {
    /// Fine-grained tile-overlap dependencies (MPK default).
    Fine,
    /// Collectives synchronize on their *whole* upstream operator
    /// (Figure 5c): disables compute–communication overlap.
    CoarseCollectives,
    /// Every operator edge is a single event — kernel-barrier semantics.
    CoarseAll,
}

/// Compiler options.
#[derive(Clone, Debug)]
pub struct CompileOptions {
    pub decompose: DecomposeConfig,
    pub granularity: DepGranularity,
    /// Disable event fusion (ablation / stats baseline).
    pub fuse: bool,
    /// Merge fork events instead of inserting Figure-6 dummy tasks
    /// (mirrors the paper's fused-epilogue operators; §6.7 reports
    /// production graphs normalize with < 1 % overhead).
    pub merge_forks: bool,
    /// Run the static race/deadlock verifier
    /// ([`crate::tgraph::verify`]) as a compile-time gate: `compile`
    /// panics if any analysis finds a violation. On by default in debug
    /// builds and tests; release callers opt in per call (or use
    /// [`compile_verified`] to inspect the report instead of panicking).
    pub verify: bool,
}

impl Default for CompileOptions {
    fn default() -> Self {
        CompileOptions {
            decompose: DecomposeConfig::default(),
            granularity: DepGranularity::Fine,
            fuse: true,
            merge_forks: true,
            verify: cfg!(debug_assertions),
        }
    }
}

/// Per-stage statistics — the Table 2 row for a compiled model.
#[derive(Clone, Copy, Debug, Default)]
pub struct StageStats {
    pub ops: usize,
    /// Non-dummy tasks after decomposition.
    pub tasks: usize,
    pub tasks_per_op: f64,
    /// Producer/consumer task pairs found by dependency analysis (the
    /// pre-fusion event count).
    pub dep_pairs: usize,
    /// Events after fusion (and normalization additions).
    pub events: usize,
    pub fusion_reduction: f64,
    /// Dummy tasks / events added by normalization.
    pub dummy_tasks: usize,
    pub norm_events_added: usize,
    /// Normalization overhead: dummy tasks as a fraction of all tasks.
    pub norm_overhead: f64,
    /// Successor-encoding footprint (bytes) without / with linearization.
    pub lin_naive_bytes: usize,
    pub lin_bytes: usize,
    pub lin_reduction: f64,
    /// Verifier coverage: overlapping same-tensor region pairs checked
    /// for happens-before ordering (0 when `CompileOptions::verify` is
    /// off).
    pub verify_pairs: usize,
    /// Verifier: direct task→task pairs encoded by the event lists.
    pub verify_hb_edges: usize,
    /// Verifier wall time, µs (0 when off).
    pub verify_us: u64,
}

/// A fully compiled tGraph ready for the runtime and the simulator.
#[derive(Clone, Debug)]
pub struct CompiledGraph {
    pub graph: CompGraph,
    pub tgraph: TGraph,
    pub linear: LinearTGraph,
    pub decomposition: Vec<OpTasks>,
}

impl CompiledGraph {
    pub fn stats(&self) -> &StageStats {
        &self.tgraph.stats
    }
}

/// Run the full pipeline. When `opt.verify` is set (the default in
/// debug builds and tests), the static race/deadlock verifier runs as a
/// gate and this function panics with the full report on any violation.
pub fn compile(graph: &CompGraph, opt: &CompileOptions) -> CompiledGraph {
    let (c, report) = compile_inner(graph, opt, opt.verify);
    if let Some(r) = report {
        assert!(
            r.is_clean(),
            "tGraph verification failed ({} ops, {} tasks):\n{}",
            graph.ops.len(),
            c.tgraph.tasks.len(),
            r.render(16)
        );
    }
    c
}

/// Run the pipeline with verification forced on and return the report
/// alongside the compiled graph instead of panicking — the entry point
/// for `mpk verify` and for callers that want the coverage stats.
pub fn compile_verified(graph: &CompGraph, opt: &CompileOptions) -> (CompiledGraph, VerifyReport) {
    let (c, report) = compile_inner(graph, opt, true);
    (c, report.expect("verification was requested"))
}

fn compile_inner(
    graph: &CompGraph,
    opt: &CompileOptions,
    verify: bool,
) -> (CompiledGraph, Option<VerifyReport>) {
    let mut stats = StageStats { ops: graph.ops.len(), ..Default::default() };
    let mut snapshots: Vec<StageSnapshot> = Vec::new();

    // (b) operator decomposition
    let decomposition = decompose(graph, &opt.decompose);
    // (b→c) dependency analysis
    let raw = analyze_deps(graph, &decomposition);
    let RawTGraph { mut tasks, events, op_task_span, dep_pairs } = raw;
    stats.tasks = tasks.len();
    stats.tasks_per_op = tasks.len() as f64 / graph.ops.len().max(1) as f64;
    stats.dep_pairs = dep_pairs;

    // coarsen (ablations) — replace fine events with per-op-edge barriers.
    let events = match opt.granularity {
        DepGranularity::Fine => events,
        g => coarsen(graph, &mut tasks, &op_task_span, g),
    };
    if verify {
        // baseline relation: the dependency events actually fed to the
        // rest of the pipeline (coarse when ablating).
        let stage = if opt.granularity == DepGranularity::Fine { "deps" } else { "coarsen" };
        snapshots.push(StageSnapshot {
            stage,
            rule: StageRule::Superset,
            tasks: tasks.clone(),
            events: events.clone(),
        });
    }

    // (c→d) event fusion
    let mut events = if opt.fuse {
        fuse_events(&mut tasks, events)
    } else {
        events
    };
    let events_after_fusion = events.len();
    stats.fusion_reduction = dep_pairs as f64 / events_after_fusion.max(1) as f64;
    if verify && opt.fuse {
        snapshots.push(StageSnapshot {
            stage: "fuse",
            rule: StageRule::Superset,
            tasks: tasks.clone(),
            events: events.clone(),
        });
    }

    if opt.merge_forks {
        events = crate::tgraph::fusion::merge_task_forks(&mut tasks, events);
        if verify {
            snapshots.push(StageSnapshot {
                stage: "merge_forks",
                rule: StageRule::Superset,
                tasks: tasks.clone(),
                events: events.clone(),
            });
        }
    }

    // §5.2 hybrid-launch classification (operator granularity).
    classify_launch(graph, &mut tasks, &op_task_span, &decomposition);

    // (d→e) normalization
    let nstats = normalize(&mut tasks, &mut events);
    stats.dummy_tasks = nstats.dummy_tasks_added;
    stats.norm_events_added = nstats.events_added;
    stats.norm_overhead = nstats.dummy_tasks_added as f64 / tasks.len().max(1) as f64;

    // start/end events.
    let start_event: EventId = events.len();
    events.push(EventDesc { id: start_event, in_tasks: vec![], out_tasks: vec![] });
    let end_event: EventId = events.len();
    events.push(EventDesc { id: end_event, in_tasks: vec![], out_tasks: vec![] });
    for t in tasks.iter_mut() {
        if t.dependent_events.is_empty() {
            t.dependent_events.push(start_event);
            events[start_event].out_tasks.push(t.id);
        }
        if t.trigger_events.is_empty() {
            t.trigger_events.push(end_event);
            events[end_event].in_tasks.push(t.id);
        }
    }
    stats.events = events.len();

    // (e→f) linearization
    let linear = linearize(&tasks, &events);
    stats.lin_naive_bytes = naive_footprint_bytes(&events);
    stats.lin_bytes = linear.footprint_bytes();
    stats.lin_reduction = stats.lin_naive_bytes as f64 / stats.lin_bytes.max(1) as f64;

    let tgraph = TGraph { tasks, events, start_event, end_event, stats };
    debug_assert_eq!(tgraph.check_consistent(), Ok(()));
    debug_assert!(tgraph.is_normalized());
    let mut c = CompiledGraph { graph: graph.clone(), tgraph, linear, decomposition };
    let report = if verify {
        let r = crate::tgraph::verify::verify_pipeline(&c, &snapshots, opt);
        c.tgraph.stats.verify_pairs = r.region_pairs;
        c.tgraph.stats.verify_hb_edges = r.hb_edges;
        c.tgraph.stats.verify_us = r.wall_us;
        Some(r)
    } else {
        None
    };
    (c, report)
}

/// Replace fine-grained events with one event per operator edge for the
/// selected consumers (Figure 5c semantics).
fn coarsen(
    graph: &CompGraph,
    tasks: &mut [TaskDesc],
    span: &[(usize, usize)],
    g: DepGranularity,
) -> Vec<EventDesc> {
    for t in tasks.iter_mut() {
        t.dependent_events.clear();
        t.trigger_events.clear();
    }
    let mut events: Vec<EventDesc> = Vec::new();
    for op in &graph.ops {
        let coarse_consumer = match g {
            DepGranularity::CoarseAll => true,
            DepGranularity::CoarseCollectives => op.kind.is_comm(),
            DepGranularity::Fine => unreachable!(),
        };
        let (cfirst, ccount) = span[op.id];
        for (idx, &inp) in op.inputs.iter().enumerate() {
            let Some(pid) = graph.producer[inp] else { continue };
            let (pfirst, pcount) = span[pid];
            if coarse_consumer {
                let eid = events.len();
                let in_tasks: Vec<usize> = (pfirst..pfirst + pcount).collect();
                let out_tasks: Vec<usize> = (cfirst..cfirst + ccount).collect();
                for &t in &in_tasks {
                    tasks[t].trigger_events.push(eid);
                }
                for &t in &out_tasks {
                    tasks[t].dependent_events.push(eid);
                }
                events.push(EventDesc { id: eid, in_tasks, out_tasks });
            } else {
                // keep fine-grained pairs for non-selected consumers.
                let in_shape = &graph.tensor(inp).shape;
                for ct in cfirst..cfirst + ccount {
                    let need = op.kind.input_region(&tasks[ct].out_region, idx, in_shape);
                    for pt in pfirst..pfirst + pcount {
                        if tasks[pt].out_region.overlaps(&need) {
                            let eid = events.len();
                            events.push(EventDesc { id: eid, in_tasks: vec![pt], out_tasks: vec![ct] });
                            tasks[pt].trigger_events.push(eid);
                            tasks[ct].dependent_events.push(eid);
                        }
                    }
                }
            }
        }
    }
    events
}

/// §5.2: operators with data-dependent durations are JIT; downstream
/// operators stay JIT until a *global barrier* edge (every consumer task
/// consumes the producer's entire output) clears accumulated imbalance.
fn classify_launch(
    graph: &CompGraph,
    tasks: &mut [TaskDesc],
    span: &[(usize, usize)],
    decomposition: &[OpTasks],
) {
    let n = graph.ops.len();
    let mut jit = vec![false; n];
    for op in &graph.ops {
        if op.launch() == LaunchMode::Jit {
            jit[op.id] = true;
        }
    }
    // propagate in topo order.
    for &oid in graph.topo_order().iter() {
        let op = &graph.ops[oid];
        if jit[oid] {
            continue;
        }
        // op stays AOT if *every* jit-producing input edge is a barrier.
        let mut becomes_jit = false;
        for (idx, &inp) in op.inputs.iter().enumerate() {
            let Some(pid) = graph.producer[inp] else { continue };
            if !jit[pid] {
                continue;
            }
            if !edge_is_barrier(graph, op, idx, inp, &decomposition[oid]) {
                becomes_jit = true;
                break;
            }
        }
        if becomes_jit && op.launch_override.is_none() {
            jit[oid] = true;
        }
    }
    for op in &graph.ops {
        let mode = if jit[op.id] { LaunchMode::Jit } else { LaunchMode::Aot };
        let (first, count) = span[op.id];
        for t in first..first + count {
            tasks[t].launch = mode;
        }
    }
}

/// An edge is a global barrier when every consumer task reads the whole
/// input tensor (e.g. row-wise RMSNorm at batch 1): the consumer cannot
/// start until all upstream tasks finish, flushing JIT imbalance.
fn edge_is_barrier(
    graph: &CompGraph,
    op: &crate::ops::Op,
    idx: usize,
    inp: crate::ops::TensorId,
    decomp: &OpTasks,
) -> bool {
    let shape = &graph.tensor(inp).shape;
    let full = Region::full(shape);
    decomp
        .tiles
        .iter()
        .all(|tile| op.kind.input_region(tile, idx, shape).contains(&full))
}

/// Convenience: count launch modes over non-dummy tasks.
pub fn launch_histogram(tg: &TGraph) -> (usize, usize) {
    let mut jit = 0;
    let mut aot = 0;
    for t in &tg.tasks {
        if t.kind.is_dummy() {
            continue;
        }
        match t.launch {
            LaunchMode::Jit => jit += 1,
            LaunchMode::Aot => aot += 1,
        }
    }
    (jit, aot)
}

/// Convenience: does this compiled graph contain communication tasks?
pub fn has_comm(tg: &TGraph) -> bool {
    tg.tasks.iter().any(|t| t.kind.is_comm())
}

/// Human-readable mnemonic for a task (diagnostics / traces).
pub fn task_label(graph: &CompGraph, t: &TaskDesc) -> String {
    match &t.kind {
        TaskKind::Compute { op, kind } => {
            format!("{}:{}{}", graph.ops[*op].name, kind.mnemonic(), t.out_region)
        }
        TaskKind::Transfer { src_dev, dst_dev, .. } => format!("XFER {src_dev}->{dst_dev}"),
        TaskKind::Dummy => "DUMMY".into(),
        TaskKind::IterPrep => "ITER_PREP".into(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{build_decode_graph, GraphOptions, ModelConfig};
    use crate::ops::{DType, OpKind};
    use crate::tgraph::linearize::verify;

    fn compile_tiny() -> CompiledGraph {
        let cfg = ModelConfig::tiny();
        let g = build_decode_graph(&cfg, &GraphOptions { batch: 2, kv_len: 16, ..Default::default() });
        compile(&g, &CompileOptions { decompose: DecomposeConfig { target_tasks: 16, min_tile_cols: 8 }, ..Default::default() })
    }

    #[test]
    fn pipeline_produces_consistent_normalized_graph() {
        let c = compile_tiny();
        c.tgraph.check_consistent().unwrap();
        assert!(c.tgraph.is_normalized());
        verify(&c.linear, &c.tgraph.tasks, &c.tgraph.events).unwrap();
    }

    #[test]
    fn fusion_reduces_events_substantially() {
        let c = compile_tiny();
        let s = c.stats();
        assert!(s.fusion_reduction > 2.0, "fusion reduction {}", s.fusion_reduction);
        assert!(s.events < s.dep_pairs);
    }

    #[test]
    fn linearization_shrinks_footprint() {
        let c = compile_tiny();
        let s = c.stats();
        assert!(s.lin_reduction > 1.0, "lin {} naive {}", s.lin_bytes, s.lin_naive_bytes);
    }

    #[test]
    fn attention_tasks_are_jit_matmul_aot() {
        let c = compile_tiny();
        let (jit, aot) = launch_histogram(&c.tgraph);
        assert!(jit > 0 && aot > 0);
        for t in &c.tgraph.tasks {
            if let TaskKind::Compute { kind: OpKind::Attention { .. }, .. } = &t.kind {
                assert_eq!(t.launch, LaunchMode::Jit);
            }
            if let TaskKind::Compute { kind: OpKind::Embedding, .. } = &t.kind {
                assert_eq!(t.launch, LaunchMode::Aot);
            }
        }
    }

    #[test]
    fn coarse_collectives_creates_operator_barriers() {
        let mut g = CompGraph::new();
        let x = g.input("x", vec![2, 64], DType::BF16);
        let w = g.param("w", vec![64, 256], DType::BF16);
        let y = g.op("mm", OpKind::MatMul, &[x, w], vec![2, 256], DType::BF16);
        g.op("ar", OpKind::AllReduce { world: 4 }, &[y], vec![2, 256], DType::BF16);
        let fine = compile(&g, &CompileOptions::default());
        let coarse = compile(
            &g,
            &CompileOptions { granularity: DepGranularity::CoarseCollectives, ..Default::default() },
        );
        // coarse: each AR task waits on ALL matmul tasks → more pairs encoded.
        let fine_deps: usize = fine.stats().dep_pairs;
        assert!(coarse.tgraph.check_consistent().is_ok());
        let coarse_max_required = coarse.linear.required.iter().max().copied().unwrap_or(0);
        assert!(coarse_max_required >= fine_deps.min(2), "coarse barrier should gate on many tasks");
    }

    #[test]
    fn moe_model_compiles() {
        let mut cfg = ModelConfig::qwen3_30b_a3b();
        cfg.layers = 2; // keep the test fast
        let g = build_decode_graph(&cfg, &GraphOptions { batch: 4, kv_len: 32, ..Default::default() });
        let c = compile(&g, &CompileOptions::default());
        c.tgraph.check_consistent().unwrap();
        assert!(c.tgraph.is_normalized());
    }

    #[test]
    fn no_fusion_option_keeps_pair_events() {
        let cfg = ModelConfig::tiny();
        let g = build_decode_graph(&cfg, &GraphOptions { batch: 1, kv_len: 8, lm_head: false, ..Default::default() });
        let fused = compile(&g, &CompileOptions::default());
        let unfused = compile(&g, &CompileOptions { fuse: false, ..Default::default() });
        assert!(unfused.tgraph.events.len() > fused.tgraph.events.len());
        unfused.tgraph.check_consistent().unwrap();
    }
}
