//! tGraph normalization (§4.1, Figure 6).
//!
//! Bounds the dependency metadata per task: after normalization every
//! task has at most one dependent event and at most one triggering event,
//! so the runtime's task descriptor stores two event ids instead of
//! variable-length lists. Tasks with excess fan-out (Figure 6a) or
//! fan-in (Figure 6b) are rewritten by inserting a fresh event plus one
//! *empty task* per original event.

use crate::ops::{LaunchMode, Region};
use crate::tgraph::task::{EventDesc, TaskDesc, TaskKind};

/// Statistics about the rewrites applied.
#[derive(Clone, Copy, Debug, Default)]
pub struct NormalizeStats {
    pub fanout_rewrites: usize,
    pub fanin_rewrites: usize,
    pub dummy_tasks_added: usize,
    pub events_added: usize,
}

/// Normalize in place. Returns rewrite statistics.
pub fn normalize(tasks: &mut Vec<TaskDesc>, events: &mut Vec<EventDesc>) -> NormalizeStats {
    let mut stats = NormalizeStats::default();
    let n0 = tasks.len();

    // -- Figure 6a: reduce fan-out to one -------------------------------
    for tid in 0..n0 {
        if tasks[tid].trigger_events.len() <= 1 {
            continue;
        }
        stats.fanout_rewrites += 1;
        let originals = std::mem::take(&mut tasks[tid].trigger_events);
        // new event e' triggered by T0 alone.
        let eprime = events.len();
        events.push(EventDesc { id: eprime, in_tasks: vec![tid], out_tasks: Vec::new() });
        stats.events_added += 1;
        tasks[tid].trigger_events.push(eprime);
        for ei in originals {
            // dummy task: depends on e', triggers the original event.
            let did = tasks.len();
            tasks.push(TaskDesc {
                id: did,
                kind: TaskKind::Dummy,
                out_region: Region::new(vec![]),
                launch: LaunchMode::Aot,
                dependent_events: vec![eprime],
                trigger_events: vec![ei],
                device: tasks[tid].device,
            });
            stats.dummy_tasks_added += 1;
            events[eprime].out_tasks.push(did);
            // rewire the original event: replace T0 by the dummy.
            let e = &mut events[ei];
            e.in_tasks.retain(|&t| t != tid);
            e.in_tasks.push(did);
            e.in_tasks.sort_unstable();
        }
    }

    // -- Figure 6b: reduce fan-in to one ---------------------------------
    let n1 = tasks.len();
    for tid in 0..n1 {
        if tasks[tid].dependent_events.len() <= 1 {
            continue;
        }
        stats.fanin_rewrites += 1;
        let originals = std::mem::take(&mut tasks[tid].dependent_events);
        let eprime = events.len();
        events.push(EventDesc { id: eprime, in_tasks: Vec::new(), out_tasks: vec![tid] });
        stats.events_added += 1;
        tasks[tid].dependent_events.push(eprime);
        for ei in originals {
            let did = tasks.len();
            tasks.push(TaskDesc {
                id: did,
                kind: TaskKind::Dummy,
                out_region: Region::new(vec![]),
                launch: LaunchMode::Aot,
                dependent_events: vec![ei],
                trigger_events: vec![eprime],
                device: tasks[tid].device,
            });
            stats.dummy_tasks_added += 1;
            events[eprime].in_tasks.push(did);
            let e = &mut events[ei];
            e.out_tasks.retain(|&t| t != tid);
            e.out_tasks.push(did);
            e.out_tasks.sort_unstable();
        }
        events[eprime].in_tasks.sort_unstable();
    }
    stats
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk_task(id: usize, deps: &[usize], trigs: &[usize]) -> TaskDesc {
        TaskDesc {
            id,
            kind: TaskKind::Dummy,
            out_region: Region::new(vec![]),
            launch: LaunchMode::Aot,
            dependent_events: deps.to_vec(),
            trigger_events: trigs.to_vec(),
            device: 0,
        }
    }

    fn check(tasks: &[TaskDesc], events: &[EventDesc]) {
        // full bidirectional consistency + normalization property.
        for t in tasks {
            assert!(t.dependent_events.len() <= 1, "task {} fan-in", t.id);
            assert!(t.trigger_events.len() <= 1, "task {} fan-out", t.id);
            for &e in &t.dependent_events {
                assert!(events[e].out_tasks.contains(&t.id));
            }
            for &e in &t.trigger_events {
                assert!(events[e].in_tasks.contains(&t.id));
            }
        }
        for e in events {
            for &t in &e.in_tasks {
                assert!(tasks[t].trigger_events.contains(&e.id));
            }
            for &t in &e.out_tasks {
                assert!(tasks[t].dependent_events.contains(&e.id));
            }
        }
    }

    #[test]
    fn fanout_rewrite_matches_figure_6a() {
        // T0 triggers e0 and e1 (each feeding one consumer task).
        let mut tasks = vec![mk_task(0, &[], &[0, 1]), mk_task(1, &[0], &[]), mk_task(2, &[1], &[])];
        let mut events = vec![
            EventDesc { id: 0, in_tasks: vec![0], out_tasks: vec![1] },
            EventDesc { id: 1, in_tasks: vec![0], out_tasks: vec![2] },
        ];
        let stats = normalize(&mut tasks, &mut events);
        assert_eq!(stats.fanout_rewrites, 1);
        assert_eq!(stats.dummy_tasks_added, 2);
        check(&tasks, &events);
        // dependency is preserved transitively: T0 -> e' -> dummies -> e0/e1.
        let eprime = tasks[0].trigger_events[0];
        assert_eq!(events[eprime].in_tasks, vec![0]);
        assert_eq!(events[eprime].out_tasks.len(), 2);
    }

    #[test]
    fn fanin_rewrite_matches_figure_6b() {
        let mut tasks = vec![mk_task(0, &[], &[0]), mk_task(1, &[], &[1]), mk_task(2, &[0, 1], &[])];
        let mut events = vec![
            EventDesc { id: 0, in_tasks: vec![0], out_tasks: vec![2] },
            EventDesc { id: 1, in_tasks: vec![1], out_tasks: vec![2] },
        ];
        let stats = normalize(&mut tasks, &mut events);
        assert_eq!(stats.fanin_rewrites, 1);
        assert_eq!(stats.dummy_tasks_added, 2);
        check(&tasks, &events);
    }

    #[test]
    fn already_normal_graph_untouched() {
        let mut tasks = vec![mk_task(0, &[], &[0]), mk_task(1, &[0], &[])];
        let mut events = vec![EventDesc { id: 0, in_tasks: vec![0], out_tasks: vec![1] }];
        let stats = normalize(&mut tasks, &mut events);
        assert_eq!(stats.dummy_tasks_added, 0);
        assert_eq!(tasks.len(), 2);
        assert_eq!(events.len(), 1);
        check(&tasks, &events);
    }

    #[test]
    fn combined_fanin_and_fanout() {
        // diamond: T0 -> {e0, e1}; e0 -> T1 -> e2; e1 -> T2 -> e3; {e2, e3} -> T3.
        let mut tasks = vec![
            mk_task(0, &[], &[0, 1]),
            mk_task(1, &[0], &[2]),
            mk_task(2, &[1], &[3]),
            mk_task(3, &[2, 3], &[]),
        ];
        let mut events = vec![
            EventDesc { id: 0, in_tasks: vec![0], out_tasks: vec![1] },
            EventDesc { id: 1, in_tasks: vec![0], out_tasks: vec![2] },
            EventDesc { id: 2, in_tasks: vec![1], out_tasks: vec![3] },
            EventDesc { id: 3, in_tasks: vec![2], out_tasks: vec![3] },
        ];
        let stats = normalize(&mut tasks, &mut events);
        assert_eq!(stats.fanout_rewrites, 1);
        assert_eq!(stats.fanin_rewrites, 1);
        check(&tasks, &events);
    }
}
