//! GPU roofline models for the three evaluated generations (§6.2,
//! Table 1) plus the calibrated runtime constants the discrete-event
//! simulator uses. Absolute numbers are public-spec rooflines; the
//! efficiency factors are calibrated so the §6.3/§6.6 anchors hold
//! (Qwen3-8B on A100: ~10 ms bandwidth bound, 12.5 ms MPK, 14.5 ms
//! baseline; 3.8 µs eager / 0.8 µs CUDA-graph launches).

/// A GPU model for the simulator.
#[derive(Clone, Copy, Debug)]
pub struct GpuSpec {
    pub name: &'static str,
    pub sms: usize,
    /// Worker / scheduler split (Table 1).
    pub workers: usize,
    pub schedulers: usize,
    /// HBM bandwidth in bytes/µs (= GB/s × 1e-3 × 1e9 ... stored as B/µs).
    pub hbm_bytes_per_us: f64,
    /// Dense bf16 peak in flops/µs across the whole GPU.
    pub peak_flops_per_us: f64,
    /// Shared-memory pages per SM (32 KB pages, §6.2).
    pub smem_pages: usize,
    /// Kernel-launch overheads (§6.6), µs.
    pub launch_us_eager: f64,
    pub launch_us_graph: f64,
    /// Per-task dispatch costs in the mega-kernel (Figure 8): JIT pays
    /// two queue synchronizations, AOT one event check.
    pub jit_dispatch_us: f64,
    pub aot_check_us: f64,
    /// Sustained fraction of the per-SM bandwidth share reached by a
    /// task's load loop: cross-task pipelining keeps the memory pipe
    /// full across task boundaries (§5.3); without it each task restarts
    /// the pipeline cold. Calibrated to the Figure 12 ablation (1.2–1.3×).
    pub bw_eff_pipelined: f64,
    pub bw_eff_unpipelined: f64,
    /// Sustained efficiency of a monolithic well-tuned kernel (cuBLAS /
    /// FlashInfer class): intra-kernel pipelining but a cold start per
    /// kernel.
    pub bw_eff_kernel: f64,
    /// MXU/tensor-core sustained fraction for task-sized GEMMs.
    pub compute_eff: f64,
}

impl GpuSpec {
    pub fn a100() -> Self {
        GpuSpec {
            name: "A100",
            sms: 108,
            workers: 104,
            schedulers: 16,
            hbm_bytes_per_us: 1.6e6, // 1.6 TB/s (§6.3 uses this figure)
            peak_flops_per_us: 312e6, // 312 TFLOPS bf16
            smem_pages: 5,
            launch_us_eager: 3.8,
            launch_us_graph: 0.8,
            jit_dispatch_us: 0.30,
            aot_check_us: 0.12,
            bw_eff_pipelined: 0.95,
            bw_eff_unpipelined: 0.75,
            bw_eff_kernel: 0.80,
            compute_eff: 0.60,
        }
    }

    pub fn h100() -> Self {
        GpuSpec {
            name: "H100",
            sms: 132,
            workers: 128,
            schedulers: 16,
            hbm_bytes_per_us: 3.35e6, // 3.35 TB/s
            peak_flops_per_us: 990e6,
            smem_pages: 7,
            launch_us_eager: 3.8,
            launch_us_graph: 0.8,
            jit_dispatch_us: 0.25,
            aot_check_us: 0.10,
            bw_eff_pipelined: 0.95,
            bw_eff_unpipelined: 0.75,
            bw_eff_kernel: 0.80,
            compute_eff: 0.60,
        }
    }

    pub fn b200() -> Self {
        GpuSpec {
            name: "B200",
            sms: 148,
            workers: 144,
            schedulers: 16,
            hbm_bytes_per_us: 8.0e6, // 8 TB/s
            peak_flops_per_us: 2250e6,
            smem_pages: 7,
            launch_us_eager: 3.8,
            launch_us_graph: 0.8,
            jit_dispatch_us: 0.20,
            aot_check_us: 0.08,
            bw_eff_pipelined: 0.95,
            bw_eff_unpipelined: 0.75,
            bw_eff_kernel: 0.80,
            compute_eff: 0.60,
        }
    }

    pub fn all() -> Vec<GpuSpec> {
        vec![Self::a100(), Self::h100(), Self::b200()]
    }

    pub fn by_name(name: &str) -> Option<GpuSpec> {
        Self::all().into_iter().find(|g| g.name.eq_ignore_ascii_case(name))
    }

    /// Per-worker bandwidth share at full occupancy, bytes/µs.
    pub fn bw_share(&self) -> f64 {
        self.hbm_bytes_per_us / self.workers as f64
    }

    /// Per-worker compute share, flops/µs.
    pub fn flops_share(&self) -> f64 {
        self.peak_flops_per_us / self.workers as f64
    }
}

/// Inter-GPU link model (NVLink within a node) for §6.5.
#[derive(Clone, Copy, Debug)]
pub struct LinkSpec {
    /// Per-GPU unidirectional bandwidth, bytes/µs.
    pub bytes_per_us: f64,
    /// Fixed latency per in-kernel transfer task (NVSHMEM put + signal),
    /// µs — far below NCCL's host-launched collectives.
    pub latency_us: f64,
    /// Latency of a host-launched collective kernel (NCCL class), for
    /// the kernel-per-operator baselines, µs.
    pub nccl_launch_us: f64,
}

impl LinkSpec {
    pub fn nvlink_h100() -> Self {
        // 900 GB/s bidirectional → 450 GB/s per direction.
        LinkSpec { bytes_per_us: 450e3, latency_us: 1.5, nccl_launch_us: 4.0 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_worker_scheduler_split() {
        // Table 1: workers = SMs - 4, 16 scheduler warps on 4 SMs.
        for g in GpuSpec::all() {
            assert_eq!(g.workers, g.sms - 4, "{}", g.name);
            assert_eq!(g.schedulers, 16, "{}", g.name);
        }
        assert_eq!(GpuSpec::a100().sms, 108);
        assert_eq!(GpuSpec::h100().sms, 132);
        assert_eq!(GpuSpec::b200().sms, 148);
    }

    #[test]
    fn smem_pages_match_paper() {
        // §6.2: 5, 7, 7 pages of 32 KB on A100/H100/B200.
        assert_eq!(GpuSpec::a100().smem_pages, 5);
        assert_eq!(GpuSpec::h100().smem_pages, 7);
        assert_eq!(GpuSpec::b200().smem_pages, 7);
    }

    #[test]
    fn qwen8b_bandwidth_bound_anchor() {
        // §6.3: 16 GB of parameters at 1.6 TB/s ≈ 10 ms per token.
        let g = GpuSpec::a100();
        let params_bytes = 16.0e9;
        let us = params_bytes / g.hbm_bytes_per_us;
        assert!((us - 10_000.0).abs() < 500.0, "{us}");
    }

    #[test]
    fn by_name_lookup() {
        assert_eq!(GpuSpec::by_name("b200").unwrap().name, "B200");
        assert!(GpuSpec::by_name("V100").is_none());
    }
}
