//! Per-task roofline costs for the discrete-event simulator.
//!
//! Each task gets a `preload` (device-memory bytes at the worker's
//! bandwidth share) and a `compute` (flops at the worker's MXU share)
//! duration; communication tasks instead cost link time. The bandwidth
//! *efficiency* applied to the preload is where cross-task pipelining
//! shows up (see [`crate::sim::gpu::GpuSpec::bw_eff_pipelined`]).

use crate::ops::{LaunchMode, OpKind};
use crate::sim::gpu::{GpuSpec, LinkSpec};
use crate::tgraph::{CompiledGraph, TaskKind};

/// Precomputed cost of one task, µs (before efficiency scaling).
#[derive(Clone, Copy, Debug, Default)]
pub struct TaskCost {
    /// Device-memory traffic at full per-worker share.
    pub preload_us: f64,
    /// MXU/CUDA-core time at per-worker share.
    pub compute_us: f64,
    /// Inter-GPU transfer time (comm tasks), including link latency.
    pub comm_us: f64,
    /// Dispatch overhead by launch mode.
    pub dispatch_us: f64,
    /// Shared-memory pages needed while resident.
    pub pages: usize,
    pub is_comm: bool,
}

impl TaskCost {
    /// Execution time with a given bandwidth efficiency (dispatch
    /// overhead excluded — the engine accounts it separately).
    pub fn exec_us(&self, bw_eff: f64, compute_eff: f64) -> f64 {
        self.preload_us / bw_eff + self.compute_us / compute_eff + self.comm_us
    }
}

/// Compute costs for every task of a compiled graph.
pub fn task_costs(c: &CompiledGraph, gpu: &GpuSpec, link: Option<&LinkSpec>) -> Vec<TaskCost> {
    task_costs_with_variance(c, gpu, link, 0.35)
}

/// Like [`task_costs`], with explicit attention-duration variance.
///
/// Decode attention is data-dependent (requests have different sequence
/// lengths, §5.2); `variance` scales each request row's attention tasks
/// deterministically within `[1-v, 1+v]`. This staggering is what JIT
/// launch balances and what fine-grained events exploit — setting it to
/// 0 models perfectly uniform requests.
pub fn task_costs_with_variance(
    c: &CompiledGraph,
    gpu: &GpuSpec,
    link: Option<&LinkSpec>,
    variance: f64,
) -> Vec<TaskCost> {
    let g = &c.graph;
    let bw = gpu.bw_share();
    let fl = gpu.flops_share();
    c.tgraph
        .tasks
        .iter()
        .map(|t| match &t.kind {
            TaskKind::Dummy => TaskCost::default(),
            TaskKind::IterPrep => TaskCost {
                compute_us: 0.5,
                dispatch_us: gpu.aot_check_us,
                ..Default::default()
            },
            TaskKind::Transfer { bytes, .. } => {
                let l = link.expect("transfer task without link spec");
                TaskCost {
                    comm_us: *bytes as f64 / l.bytes_per_us + l.latency_us,
                    dispatch_us: dispatch(gpu, t.launch),
                    pages: 1,
                    is_comm: true,
                    ..Default::default()
                }
            }
            TaskKind::Compute { op, kind } => {
                let op = &g.ops[*op];
                let in_shapes = g.in_shapes(op);
                let elem = g.tensor(op.output).dtype.size();
                let mut flops = kind.flops(&t.out_region, &in_shapes) as f64;
                let mut bytes = kind.bytes(&t.out_region, &in_shapes, elem) as f64;
                if let OpKind::Attention { .. } = kind {
                    // per-request sequence-length variance: deterministic
                    // hash of the request row.
                    let row = t.out_region.dims[0].0 as u64;
                    let h = row.wrapping_mul(0x9E37_79B9_7F4A_7C15) >> 40;
                    let f = 1.0 + variance * ((h % 1000) as f64 / 500.0 - 1.0);
                    flops *= f;
                    bytes *= f;
                }
                if let OpKind::AllReduce { world } = kind {
                    // in-kernel ring transfer: bytes already account the
                    // 2(w-1)/w factor; ride the link, not HBM.
                    let l = link.expect("AllReduce task without link spec");
                    let _ = world;
                    TaskCost {
                        comm_us: bytes / l.bytes_per_us + l.latency_us,
                        compute_us: flops / fl,
                        dispatch_us: dispatch(gpu, t.launch),
                        pages: 2,
                        is_comm: true,
                        ..Default::default()
                    }
                } else {
                    TaskCost {
                        preload_us: bytes / bw,
                        compute_us: flops / fl,
                        dispatch_us: dispatch(gpu, t.launch),
                        pages: (crate::megakernel::task_smem_bytes(&t.kind, elem)
                            / crate::megakernel::PAGE_BYTES)
                            .max(1),
                        is_comm: false,
                        ..Default::default()
                    }
                }
            }
        })
        .collect()
}

fn dispatch(gpu: &GpuSpec, mode: LaunchMode) -> f64 {
    match mode {
        LaunchMode::Jit => gpu.jit_dispatch_us,
        LaunchMode::Aot => gpu.aot_check_us,
    }
}

/// Whole-operator cost for the kernel-per-operator baselines: all tasks
/// of the op run as one kernel across all workers (wave-quantized), at
/// monolithic-kernel efficiency.
pub fn op_kernel_us(
    c: &CompiledGraph,
    costs: &[TaskCost],
    op_id: usize,
    gpu: &GpuSpec,
    link: Option<&LinkSpec>,
) -> f64 {
    let span: Vec<usize> = c
        .tgraph
        .tasks
        .iter()
        .filter(|t| t.op_id() == Some(op_id) && !t.kind.is_dummy())
        .map(|t| t.id)
        .collect();
    if span.is_empty() {
        return 0.0;
    }
    let is_comm = costs[span[0]].is_comm;
    if is_comm {
        // host-launched collective: whole-tensor latency + NCCL launch.
        let total_comm: f64 = span.iter().map(|&t| costs[t].comm_us).sum();
        let l = link.expect("comm op without link");
        // tasks proceed in parallel over the link: bandwidth term is the
        // sum of bytes (link serializes), latency paid once per op.
        let lat: f64 = l.latency_us * (span.len() as f64).min(2.0);
        return total_comm - l.latency_us * span.len() as f64 + lat + l.nccl_launch_us;
    }
    let waves = span.len().div_ceil(gpu.workers) as f64;
    let max_task = span
        .iter()
        .map(|&t| costs[t].exec_us(gpu.bw_eff_kernel, gpu.compute_eff))
        .fold(0.0f64, f64::max);
    waves * max_task
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{build_decode_graph, GraphOptions, ModelConfig};
    use crate::tgraph::{compile, CompileOptions, DecomposeConfig};

    fn compiled(batch: usize) -> CompiledGraph {
        let cfg = ModelConfig::qwen3_1_7b();
        let g = build_decode_graph(&cfg, &GraphOptions { batch, kv_len: 512, ..Default::default() });
        compile(
            &g,
            &CompileOptions {
                decompose: DecomposeConfig { target_tasks: 104, min_tile_cols: 8 },
                ..Default::default()
            },
        )
    }

    #[test]
    fn decode_is_bandwidth_bound() {
        let c = compiled(1);
        let gpu = GpuSpec::a100();
        let costs = task_costs(&c, &gpu, None);
        let preload: f64 = costs.iter().map(|c| c.preload_us).sum();
        let compute: f64 = costs.iter().map(|c| c.compute_us).sum();
        assert!(preload > 5.0 * compute, "preload {preload} compute {compute}");
    }

    #[test]
    fn total_preload_close_to_param_streaming_bound() {
        let c = compiled(1);
        let gpu = GpuSpec::a100();
        let costs = task_costs(&c, &gpu, None);
        // sum over workers: total preload time × workers × share = bytes.
        let total_bytes: f64 =
            costs.iter().map(|t| t.preload_us).sum::<f64>() * gpu.bw_share();
        // the embedding table is gathered (B rows), not streamed, so
        // the bound excludes it.
        let embed = c.graph.tensor_by_name("embed.weight").unwrap().bytes() as f64;
        let param_bytes = c.graph.param_bytes() as f64 - embed;
        assert!(
            total_bytes > param_bytes && total_bytes < 1.8 * param_bytes,
            "moved {total_bytes:.2e} vs streamed params {param_bytes:.2e}"
        );
    }

    #[test]
    fn dummy_tasks_are_free() {
        let c = compiled(2);
        let gpu = GpuSpec::h100();
        let costs = task_costs(&c, &gpu, None);
        for t in &c.tgraph.tasks {
            if t.kind.is_dummy() {
                let k = costs[t.id];
                assert_eq!(k.preload_us + k.compute_us + k.comm_us, 0.0);
            }
        }
    }

    #[test]
    fn pipelining_efficiency_ratio_in_paper_band() {
        // memory-bound task: pipe vs no-pipe ratio = 0.95/0.75 ≈ 1.27.
        let gpu = GpuSpec::b200();
        let t = TaskCost { preload_us: 100.0, compute_us: 2.0, ..Default::default() };
        let ratio = t.exec_us(gpu.bw_eff_unpipelined, gpu.compute_eff)
            / t.exec_us(gpu.bw_eff_pipelined, gpu.compute_eff);
        assert!((1.15..=1.35).contains(&ratio), "ratio {ratio}");
    }
}
