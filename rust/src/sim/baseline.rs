//! Kernel-per-operator baselines (§6.3): the vLLM-, SGLang- and
//! PyTorch-class execution models the paper compares against.
//!
//! All three run the same operator graph sequentially with kernel
//! barriers: each op is one kernel (wave-quantized over workers), a
//! launch overhead precedes it, collectives are host-launched and never
//! overlap compute, and the CPU-side page-allocation / request-
//! scheduling work adds a per-iteration gap (§6.3 lists those three
//! overheads; §6.6 calibrates the launch costs).

use crate::sim::cost::{op_kernel_us, task_costs};
use crate::sim::gpu::{GpuSpec, LinkSpec};
use crate::tgraph::CompiledGraph;

/// Launch mechanism of a baseline system.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LaunchModel {
    /// Every kernel launched eagerly from the host (3.8 µs, §6.6).
    Eager,
    /// CUDA-graph replay (0.8 µs per kernel, §6.6).
    CudaGraph,
}

/// A kernel-per-operator serving system profile.
#[derive(Clone, Copy, Debug)]
pub struct BaselineSystem {
    pub name: &'static str,
    pub launch: LaunchModel,
    /// CPU-side scheduling / page-allocation gap per decode iteration,
    /// µs (host-device synchronization the mega-kernel eliminates).
    pub cpu_gap_us: f64,
    /// Host-side framework overhead per operator (python dispatch,
    /// shape checks) — zero under CUDA-graph replay.
    pub op_cpu_us: f64,
}

impl BaselineSystem {
    /// Native PyTorch: eager launches, compile-level kernels, large
    /// host-side gaps (the paper reports >10× vs MPK).
    pub fn pytorch() -> Self {
        BaselineSystem { name: "PyTorch", launch: LaunchModel::Eager, cpu_gap_us: 400.0, op_cpu_us: 12.0 }
    }

    /// vLLM: CUDA graphs + paged attention, CPU scheduler in the loop.
    pub fn vllm() -> Self {
        BaselineSystem { name: "vLLM", launch: LaunchModel::CudaGraph, cpu_gap_us: 120.0, op_cpu_us: 0.0 }
    }

    /// SGLang: CUDA graphs, leaner host path.
    pub fn sglang() -> Self {
        BaselineSystem { name: "SGLang", launch: LaunchModel::CudaGraph, cpu_gap_us: 60.0, op_cpu_us: 0.0 }
    }

    pub fn all() -> Vec<BaselineSystem> {
        vec![Self::pytorch(), Self::vllm(), Self::sglang()]
    }
}

/// Per-iteration latency (µs) of `sys` executing the compiled graph.
pub fn simulate_baseline(
    c: &CompiledGraph,
    gpu: &GpuSpec,
    sys: &BaselineSystem,
    link: Option<&LinkSpec>,
) -> f64 {
    let costs = task_costs(c, gpu, link);
    let launch = match sys.launch {
        LaunchModel::Eager => gpu.launch_us_eager,
        LaunchModel::CudaGraph => gpu.launch_us_graph,
    };
    let mut total = sys.cpu_gap_us;
    for op in &c.graph.ops {
        let k = op_kernel_us(c, &costs, op.id, gpu, link);
        if k > 0.0 {
            total += launch + sys.op_cpu_us + k;
        }
    }
    total
}

/// Number of kernel launches per iteration (for the §6.6 ablation).
pub fn kernel_launches(c: &CompiledGraph) -> usize {
    c.graph.ops.len()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{build_decode_graph, GraphOptions, ModelConfig};
    use crate::sim::engine::{simulate_megakernel, SimOptions};
    use crate::tgraph::{compile, CompileOptions, DecomposeConfig};

    fn compiled(cfg: &ModelConfig, batch: usize, gpu: &GpuSpec) -> CompiledGraph {
        let g = build_decode_graph(cfg, &GraphOptions { batch, kv_len: 512, ..Default::default() });
        compile(
            &g,
            &CompileOptions {
                decompose: DecomposeConfig { target_tasks: gpu.workers, min_tile_cols: 8 },
                ..Default::default()
            },
        )
    }

    #[test]
    fn mpk_beats_every_baseline_at_batch_one() {
        let gpu = GpuSpec::b200();
        let c = compiled(&ModelConfig::qwen3_1_7b(), 1, &gpu);
        let mpk = simulate_megakernel(&c, &gpu, &SimOptions::default()).makespan_us;
        for sys in BaselineSystem::all() {
            let b = simulate_baseline(&c, &gpu, &sys, None);
            assert!(b > mpk, "{}: {b} vs MPK {mpk}", sys.name);
        }
    }

    #[test]
    fn speedup_band_matches_figure9() {
        // 1.0–1.7× vs the best optimized baseline across models/GPUs.
        for gpu in [GpuSpec::a100(), GpuSpec::b200()] {
            for cfg in [ModelConfig::qwen3_0_6b(), ModelConfig::qwen3_8b()] {
                let c = compiled(&cfg, 1, &gpu);
                let mpk = simulate_megakernel(&c, &gpu, &SimOptions::default()).makespan_us;
                let best = BaselineSystem::all()
                    .iter()
                    .filter(|s| s.name != "PyTorch")
                    .map(|s| simulate_baseline(&c, &gpu, s, None))
                    .fold(f64::INFINITY, f64::min);
                let speedup = best / mpk;
                assert!(
                    (1.0..=2.2).contains(&speedup),
                    "{} on {}: speedup {speedup:.2}",
                    cfg.name,
                    gpu.name
                );
            }
        }
    }

    #[test]
    fn gains_larger_on_smaller_models_and_newer_gpus() {
        // the Figure 9 trend: overheads matter more when compute/token
        // shrinks or hardware gets faster.
        let speedup = |cfg: &ModelConfig, gpu: &GpuSpec| {
            let c = compiled(cfg, 1, gpu);
            let mpk = simulate_megakernel(&c, gpu, &SimOptions::default()).makespan_us;
            let sg = simulate_baseline(&c, gpu, &BaselineSystem::sglang(), None);
            sg / mpk
        };
        let b200 = GpuSpec::b200();
        let a100 = GpuSpec::a100();
        let small_new = speedup(&ModelConfig::qwen3_0_6b(), &b200);
        let big_old = speedup(&ModelConfig::qwen3_8b(), &a100);
        assert!(small_new > big_old, "small/new {small_new:.2} <= big/old {big_old:.2}");
    }

    #[test]
    fn pytorch_gap_is_order_of_magnitude_on_small_models() {
        let gpu = GpuSpec::b200();
        let c = compiled(&ModelConfig::qwen3_0_6b(), 1, &gpu);
        let mpk = simulate_megakernel(&c, &gpu, &SimOptions::default()).makespan_us;
        let pt = simulate_baseline(&c, &gpu, &BaselineSystem::pytorch(), None);
        assert!(pt / mpk > 4.0, "PyTorch/MPK = {:.2}", pt / mpk);
    }

    #[test]
    fn launch_overhead_accounting_matches_656() {
        // §6.6: Qwen3-8B ≈ 293 kernels/token; eager 3.8 µs ≈ 1.1 ms,
        // graphs 0.8 µs ≈ 0.2 ms. Our op count is close, not identical.
        let gpu = GpuSpec::b200();
        let c = compiled(&ModelConfig::qwen3_8b(), 1, &gpu);
        let n = kernel_launches(&c);
        assert!((250..=450).contains(&n), "launches {n}");
        let eager_ms = n as f64 * gpu.launch_us_eager / 1000.0;
        assert!((0.9..=1.8).contains(&eager_ms), "eager total {eager_ms} ms");
    }
}
