//! Discrete-event GPU timing simulator: roofline models of A100/H100/
//! B200, the mega-kernel scheduling replay, and the kernel-per-operator
//! baselines — the substrate that regenerates the paper's figures.
pub mod baseline;
pub mod cost;
pub mod engine;
pub mod gpu;

pub use baseline::{kernel_launches, simulate_baseline, BaselineSystem, LaunchModel};
pub use cost::{op_kernel_us, task_costs, TaskCost};
pub use engine::{simulate_megakernel, SimOptions, SimResult};
pub use gpu::{GpuSpec, LinkSpec};
