//! Discrete-event simulation of the mega-kernel runtime under a GPU
//! roofline model. Replays the *same* tGraph and the *same* scheduling
//! policy as [`crate::megakernel`] (AOT round-robin in linearized order,
//! JIT to the least-loaded worker, head-of-line AOT blocking) with
//! calibrated per-task costs, to regenerate the paper's figures on
//! A100/H100/B200 models we don't physically have.

use crate::ops::LaunchMode;
use crate::sim::cost::{task_costs, TaskCost};
use crate::sim::gpu::{GpuSpec, LinkSpec};
use crate::tgraph::{CompiledGraph, TaskId};
use std::cmp::Reverse;
use std::collections::{BinaryHeap, VecDeque};

/// Task-dispatch policy (§6.1: "the runtime is designed to support
/// alternative policies, including globally coordinated scheduling").
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchedPolicy {
    /// Paper default: decentralized schedulers assign JIT tasks from
    /// local state; AOT tasks pre-assigned round-robin.
    Decentralized,
    /// One global work queue: perfect load information, but every
    /// dispatch pays a serialized coordination round-trip.
    GlobalQueue,
}

/// Simulation switches (the ablation knobs of §6.6).
#[derive(Clone, Copy, Debug)]
pub struct SimOptions {
    /// Cross-task software pipelining (§5.3). Off → every task pays the
    /// cold-pipe bandwidth efficiency.
    pub pipelining: bool,
    /// Link model for communication tasks (multi-GPU graphs).
    pub link: Option<LinkSpec>,
    /// Per-task completion-time jitter (DRAM contention, SM clock
    /// spread): each task's duration is scaled deterministically within
    /// `[1-j, 1+j]`. This spread is what fine-grained events exploit —
    /// a coarse barrier waits for the slowest producer, fine-grained
    /// consumers start as their own tile finishes.
    pub jitter: f64,
    pub policy: SchedPolicy,
}

impl Default for SimOptions {
    fn default() -> Self {
        SimOptions { pipelining: true, link: None, jitter: 0.10, policy: SchedPolicy::Decentralized }
    }
}

/// Result of one simulated mega-kernel invocation.
#[derive(Clone, Debug)]
pub struct SimResult {
    /// End-to-end makespan, µs.
    pub makespan_us: f64,
    /// Mean worker busy fraction.
    pub utilization: f64,
    /// Total dispatch overhead across tasks, µs.
    pub dispatch_us: f64,
    /// Number of simulated (non-dummy) tasks.
    pub tasks: usize,
}

#[derive(PartialEq)]
struct Ev(f64, usize, EvKind);

#[derive(PartialEq, Eq)]
enum EvKind {
    TaskDone(TaskId, usize),
}

impl Eq for Ev {}
impl PartialOrd for Ev {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Ev {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.partial_cmp(&other.0).unwrap_or(std::cmp::Ordering::Equal).then(self.1.cmp(&other.1))
    }
}

/// Simulate one mega-kernel execution of `c` on `gpu`.
pub fn simulate_megakernel(c: &CompiledGraph, gpu: &GpuSpec, opt: &SimOptions) -> SimResult {
    let costs = task_costs(c, gpu, opt.link.as_ref());
    let tg = &c.tgraph;
    let lin = &c.linear;
    let nw = gpu.workers;

    // AOT queues per worker, linearized round-robin (same as runtime).
    let mut aot: Vec<VecDeque<TaskId>> = vec![VecDeque::new(); nw];
    {
        let mut cursor = 0usize;
        for &tid in &lin.order {
            if tg.tasks[tid].launch == LaunchMode::Aot {
                aot[cursor % nw].push_back(tid);
                cursor += 1;
            }
        }
    }
    let mut jit: Vec<VecDeque<TaskId>> = vec![VecDeque::new(); nw];
    let mut counters: Vec<usize> = vec![0; tg.events.len()];
    let mut activated: Vec<bool> = (0..tg.events.len()).map(|e| lin.required[e] == 0).collect();
    let mut done: Vec<bool> = vec![false; tg.tasks.len()];
    let mut worker_free = vec![0.0f64; nw];
    let mut worker_busy = vec![0.0f64; nw];
    let mut worker_last_task: Vec<Option<TaskId>> = vec![None; nw];
    let mut heap: BinaryHeap<Reverse<Ev>> = BinaryHeap::new();
    let mut seq = 0usize;
    let mut dispatch_total = 0.0f64;
    let mut executed = 0usize;
    // GlobalQueue policy: a single coordinator serializes every task
    // grant; `coord_free` is when it can issue the next one.
    let mut coord_free = 0.0f64;
    let coord_cost = 2.0 * gpu.jit_dispatch_us; // global round-trip

    // JIT dispatch: earliest-free worker (decentralized least-loaded).
    let assign_jit = |jit: &mut Vec<VecDeque<TaskId>>, worker_free: &[f64], tid: TaskId| {
        let (w, _) = worker_free
            .iter()
            .enumerate()
            .map(|(i, &f)| (i, f + jit[i].len() as f64 * 0.01))
            .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .unwrap();
        jit[w].push_back(tid);
        w
    };

    // seed: start event born-activated → its JIT successors dispatched,
    // AOT successors become head-runnable.
    let start = tg.start_event;
    let mut to_dispatch: Vec<TaskId> = Vec::new();
    if let Some((f, l)) = lin.event_range[start] {
        for p in f..=l {
            let tid = lin.order[p];
            if tg.tasks[tid].launch == LaunchMode::Jit {
                to_dispatch.push(tid);
            }
        }
    }
    for tid in to_dispatch {
        assign_jit(&mut jit, &worker_free, tid);
    }

    // helper: try to start work on a worker at time `now`.
    macro_rules! try_start {
        ($w:expr, $now:expr) => {{
            let w: usize = $w;
            let now: f64 = $now;
            if worker_free[w] <= now + 1e-12 {
                let mut pick: Option<(TaskId, bool)> = None;
                if let Some(&tid) = jit[w].front() {
                    pick = Some((tid, true));
                } else if let Some(&tid) = aot[w].front() {
                    let dep = tg.tasks[tid].dependent_events[0];
                    if activated[dep] {
                        pick = Some((tid, false));
                    }
                }
                if let Some((tid, is_jit)) = pick {
                    if is_jit {
                        jit[w].pop_front();
                    } else {
                        aot[w].pop_front();
                    }
                    // global coordination: the grant serializes through
                    // one coordinator before the worker may begin.
                    let now = if opt.policy == SchedPolicy::GlobalQueue {
                        let start = now.max(coord_free) + coord_cost;
                        coord_free = start;
                        start
                    } else {
                        now
                    };
                    let cost: &TaskCost = &costs[tid];
                    // pipelining condition (§5.3): back-to-back tasks on
                    // this worker with pages available keep the memory
                    // pipe warm; otherwise the cold-pipe efficiency.
                    // §5.3: the previous task releases pages monotonically
                    // as it drains, so the next preload needs its pages to
                    // fit alongside the *residual* (≈1 page) of the
                    // draining task — not its peak footprint.
                    let warm = opt.pipelining
                        && worker_last_task[w].is_some()
                        && cost.pages < gpu.smem_pages;
                    let bw_eff = if warm { gpu.bw_eff_pipelined } else { gpu.bw_eff_unpipelined };
                    let h = (tid as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15) >> 40;
                    let jf = 1.0 + opt.jitter * ((h % 1024) as f64 / 512.0 - 1.0);
                    let dur = cost.exec_us(bw_eff, gpu.compute_eff) * jf + cost.dispatch_us;
                    dispatch_total += cost.dispatch_us;
                    let fin = now + dur.max(1e-6);
                    worker_free[w] = fin;
                    worker_busy[w] += dur;
                    worker_last_task[w] = Some(tid);
                    executed += 1;
                    seq += 1;
                    heap.push(Reverse(Ev(fin, seq, EvKind::TaskDone(tid, w))));
                }
            }
        }};
    }

    for w in 0..nw {
        try_start!(w, 0.0);
    }

    let mut makespan = 0.0f64;
    while let Some(Reverse(Ev(t, _, EvKind::TaskDone(tid, w)))) = heap.pop() {
        makespan = makespan.max(t);
        done[tid] = true;
        // notify trigger event.
        if let Some(&ev) = tg.tasks[tid].trigger_events.first() {
            counters[ev] += 1;
            if counters[ev] == lin.required[ev] {
                activated[ev] = true;
                if let Some((f, l)) = lin.event_range[ev] {
                    for p in f..=l {
                        let succ = lin.order[p];
                        if tg.tasks[succ].launch == LaunchMode::Jit {
                            let tw = assign_jit(&mut jit, &worker_free, succ);
                            try_start!(tw, t);
                        }
                    }
                }
                // wake only workers whose AOT head waits on this event
                // (§Perf: event-indexed wakeup instead of O(workers)
                // polling per activation — ~1.5x faster DES replay).
                let mut rerun = true;
                while rerun {
                    rerun = false;
                    for ww in 0..nw {
                        let head_waits = aot[ww]
                            .front()
                            .map(|&h| tg.tasks[h].dependent_events[0] == ev)
                            .unwrap_or(false);
                        if head_waits {
                            try_start!(ww, t.max(worker_free[ww]));
                        }
                    }
                }
            }
        }
        try_start!(w, t);
    }

    debug_assert_eq!(executed, tg.tasks.len(), "simulation dropped tasks");
    let util = worker_busy.iter().sum::<f64>() / (nw as f64 * makespan.max(1e-9));
    SimResult {
        makespan_us: makespan,
        utilization: util,
        dispatch_us: dispatch_total,
        tasks: tg.real_task_count(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{build_decode_graph, GraphOptions, ModelConfig};
    use crate::tgraph::{compile, CompileOptions, DecomposeConfig, DepGranularity};

    fn compiled(cfg: &ModelConfig, batch: usize, gpu: &GpuSpec, granularity: DepGranularity) -> CompiledGraph {
        let g = build_decode_graph(cfg, &GraphOptions { batch, kv_len: 512, ..Default::default() });
        compile(
            &g,
            &CompileOptions {
                decompose: DecomposeConfig { target_tasks: gpu.workers, min_tile_cols: 8 },
                granularity,
                ..Default::default()
            },
        )
    }

    #[test]
    fn qwen8b_a100_lands_near_paper_numbers() {
        // §6.3 anchor: MPK ≈ 12.5 ms/token (bound 10 ms, baselines 14.5).
        let gpu = GpuSpec::a100();
        let c = compiled(&ModelConfig::qwen3_8b(), 1, &gpu, DepGranularity::Fine);
        let r = simulate_megakernel(&c, &gpu, &SimOptions::default());
        let ms = r.makespan_us / 1000.0;
        assert!(
            (10.5..=14.0).contains(&ms),
            "Qwen3-8B A100 per-token {ms:.2} ms outside plausible band"
        );
    }

    #[test]
    fn pipelining_speeds_up_decode() {
        let gpu = GpuSpec::b200();
        let c = compiled(&ModelConfig::qwen3_1_7b(), 1, &gpu, DepGranularity::Fine);
        let with = simulate_megakernel(&c, &gpu, &SimOptions::default());
        let without = simulate_megakernel(&c, &gpu, &SimOptions { pipelining: false, link: None, ..Default::default() });
        let ratio = without.makespan_us / with.makespan_us;
        assert!((1.05..=1.40).contains(&ratio), "pipelining ratio {ratio}");
    }

    #[test]
    fn utilization_high_at_batch_one_decode() {
        let gpu = GpuSpec::h100();
        let c = compiled(&ModelConfig::qwen3_1_7b(), 1, &gpu, DepGranularity::Fine);
        let r = simulate_megakernel(&c, &gpu, &SimOptions::default());
        assert!(r.utilization > 0.4, "utilization {}", r.utilization);
    }

    #[test]
    fn makespan_monotone_in_model_size() {
        let gpu = GpuSpec::h100();
        let small = compiled(&ModelConfig::qwen3_0_6b(), 1, &gpu, DepGranularity::Fine);
        let big = compiled(&ModelConfig::qwen3_8b(), 1, &gpu, DepGranularity::Fine);
        let rs = simulate_megakernel(&small, &gpu, &SimOptions::default());
        let rb = simulate_megakernel(&big, &gpu, &SimOptions::default());
        assert!(rb.makespan_us > 3.0 * rs.makespan_us);
    }

    #[test]
    fn coarse_events_never_faster() {
        let gpu = GpuSpec::h100();
        let cfg = ModelConfig::qwen3_1_7b();
        let fine = compiled(&cfg, 4, &gpu, DepGranularity::Fine);
        let coarse = compiled(&cfg, 4, &gpu, DepGranularity::CoarseAll);
        // jitter 0 → uniform tasks, where coarse barriers can only add
        // constraints (with jitter, AOT head-of-line order can favor the
        // coarse schedule — the artifact JIT launch exists to fix).
        let opt = SimOptions { jitter: 0.0, ..Default::default() };
        let rf = simulate_megakernel(&fine, &gpu, &opt);
        let rc = simulate_megakernel(&coarse, &gpu, &opt);
        assert!(
            rc.makespan_us >= rf.makespan_us * 0.999,
            "coarse {} < fine {}",
            rc.makespan_us,
            rf.makespan_us
        );
    }

    #[test]
    fn dispatch_overhead_small_fraction() {
        // §6.6: in-kernel scheduler ≈ 0.28% of runtime.
        let gpu = GpuSpec::b200();
        let c = compiled(&ModelConfig::qwen3_8b(), 1, &gpu, DepGranularity::Fine);
        let r = simulate_megakernel(&c, &gpu, &SimOptions::default());
        let frac = r.dispatch_us / (r.makespan_us * gpu.workers as f64);
        assert!(frac < 0.02, "dispatch fraction {frac}");
    }
}
