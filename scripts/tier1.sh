#!/usr/bin/env bash
# Tier-1 gate for the rust_pallas crate: release build, test suite,
# clippy with warnings denied, and a rustdoc gate (broken intra-doc
# links are denied at the crate root, so the public API must document
# cleanly); an optional miri pass over the unsafe surface (the tensor
# arena plus the pool's lifetime-erased channel crossing — skipped with
# a warning when miri is absent); then (best-effort) the perf-trajectory
# benches so BENCH_launch_overhead.json, BENCH_store_hotpath.json,
# BENCH_weight_arena.json, BENCH_exec_into.json,
# BENCH_step_overhead.json, BENCH_cpu_backend.json,
# BENCH_saturation.json, BENCH_transport.json, BENCH_paged_kv.json,
# and BENCH_verify.json track the hot paths across PRs
# (spawn-per-iteration vs persistent runtime; locked-clone vs
# borrowed-view tile reads; per-session vs shared-arena weight init;
# alloc-per-call vs write-into pool outputs; step() bookkeeping vs the
# kernel iteration inside it; the native CPU backend's per-op kernels
# and fused decode step; admission latency and shed rate with the
# serving front-end offered 2x capacity; loopback TCP round-trip
# latency and streaming frames/s through the wire transport; paged-KV
# admission cold vs prefix-hit and the decode-step price of block-table
# indirection). The exec_into/step/cpu_backend records carry the
# backend identity they were measured on.
#
# Usage: scripts/tier1.sh [--no-bench]
set -euo pipefail
ROOT="$(cd "$(dirname "$0")/.." && pwd)"
# the crate manifest lives in rust/ (examples stay at the repo level,
# wired up via explicit [[example]] paths).
cd "$ROOT/rust"
# AOT artifacts are built at the repo root (`make artifacts` /
# `python -m compile.aot --out ../artifacts`); test binaries now run
# with cwd=rust/, so anchor the lookup or the artifact-gated tests
# would skip vacuously.
export MPK_ARTIFACTS="${MPK_ARTIFACTS:-$ROOT/artifacts}"

# Unsafe-confinement lint: the crate's entire unsafe surface must stay
# inside the audited modules listed below (the tensor arena, the pool's
# lifetime-erased channel crossing, and the megakernel runtime's MPMC
# queue + scoped executor borrow — each carries a memory-model note and
# SAFETY comments; store.rs documents the full surface). The crate root
# additionally carries #![deny(unsafe_op_in_unsafe_fn)] so every raw
# operation sits in an explicit inner `unsafe {}` block. Pure text
# check, so it runs even when the toolchain is absent. The pattern
# matches unsafe *code* tokens (`unsafe fn|impl|extern|{`), not the
# bare word, so doc comments and the deny attribute don't trip it.
echo "== tier1: unsafe-confinement lint =="
UNSAFE_ALLOWLIST=(
    "src/exec/store.rs"
    "src/runtime/pool.rs"
    "src/megakernel/queue.rs"
    "src/megakernel/runtime.rs"
)
unsafe_violations=0
while IFS= read -r f; do
    rel="${f#"$ROOT/rust/"}"
    allowed=0
    for a in "${UNSAFE_ALLOWLIST[@]}"; do
        [[ "$rel" == "$a" ]] && allowed=1 && break
    done
    if [[ "$allowed" == "0" ]]; then
        echo "tier1: FAIL: \`unsafe\` outside the audited allowlist: $rel" >&2
        grep -n "unsafe" "$f" >&2 || true
        unsafe_violations=1
    fi
done < <(grep -rlE 'unsafe (fn|impl|extern)|unsafe *\{' "$ROOT/rust/src" --include="*.rs" || true)
if [[ "$unsafe_violations" != "0" ]]; then
    echo "tier1: new unsafe code must be confined to the audited modules" >&2
    echo "tier1: (see the memory-model note in rust/src/exec/store.rs)" >&2
    exit 3
fi
echo "tier1: unsafe confined to: ${UNSAFE_ALLOWLIST[*]}"

if ! command -v cargo >/dev/null 2>&1; then
    echo "tier1: cargo not found on PATH — cannot build/test in this environment" >&2
    echo "tier1: (the repo's CI image bakes in the toolchain; locally: rustup default stable)" >&2
    # A skipped gate must not look like a green gate: exit nonzero
    # unless the caller explicitly acknowledges the missing toolchain.
    if [[ "${MPK_ALLOW_MISSING_TOOLCHAIN:-0}" == "1" ]]; then
        echo "tier1: SKIPPED (MPK_ALLOW_MISSING_TOOLCHAIN=1)" >&2
        exit 0
    fi
    exit 2
fi

echo "== tier1: cargo build --release =="
cargo build --release

echo "== tier1: cargo test -q =="
cargo test -q

# Real numerics with no artifacts dir and no PJRT library: the native
# CPU backend must decode the tiny model end to end from the compiled-in
# manifest alone. MPK_ARTIFACTS points at a directory that cannot
# exist, so this step proves the artifact-free path (a regression that
# silently starts requiring artifacts fails here, not on a user's
# machine).
echo "== tier1: real-numerics serve on the native CPU backend (no artifacts) =="
MPK_ARTIFACTS="$ROOT/nonexistent-artifacts-$$" \
    cargo run --release --quiet -- serve --requests 4 --batch 2 --backend cpu

# Static race/deadlock verification over every built-in model config
# under every DepGranularity (exercises the tgraph/verify.rs analyses
# end-to-end and seeds a small mutation sweep per graph to prove the
# analyzer still catches broken edges). Nonzero exit on any violation.
echo "== tier1: mpk verify (static race/deadlock gate) =="
# 8 mutations per config keeps the local gate snappy; CI runs a larger
# sweep (32) as its own named step.
cargo run --release --quiet -- verify --mutations 8

echo "== tier1: cargo clippy -- -D warnings =="
cargo clippy --all-targets -- -D warnings

# The public API must document cleanly: the crate root carries
# #![deny(rustdoc::broken_intra_doc_links)], so a stale [`link`] in any
# doc comment fails this gate rather than silently degrading the docs.
echo "== tier1: cargo doc --no-deps =="
cargo doc --no-deps --quiet

# The unsafe surface is the tensor arena (rust/src/exec/store.rs) plus
# the pool's lifetime-erased channel crossing (RawValue/RawOutView in
# rust/src/runtime/pool.rs — the OutView accessor and cross-thread
# scatter tests exercise the erase → cross-thread write → reply shape;
# backends themselves are unsafe-free and dispatch through it);
# when miri is installed, run both under the interpreter to check the
# aliasing contracts (UB detection). Like the missing-cargo path above,
# absence is a loud skip, not a silent green.
if cargo miri --version >/dev/null 2>&1; then
    echo "== tier1: cargo miri test (arena aliasing + pool channel-crossing contracts) =="
    cargo miri test --lib -- exec::store runtime::pool
else
    echo "tier1: miri not installed — skipping aliasing gates (rustup component add miri)" >&2
fi

if [[ "${1:-}" != "--no-bench" ]]; then
    echo "== tier1: launch_overhead bench (perf trajectory) =="
    # The benches are plain main() binaries (criterion unavailable
    # offline); each writes its JSON record to the repo root via the
    # MPK_BENCH_*JSON env vars.
    MPK_BENCH_JSON="$ROOT/BENCH_launch_overhead.json" \
        cargo bench --bench launch_overhead ||
        echo "tier1: bench skipped (non-fatal)" >&2
    # `if` (not `&&`) so a missing bench file cannot trip errexit.
    if [[ -f "$ROOT/BENCH_launch_overhead.json" ]]; then cat "$ROOT/BENCH_launch_overhead.json"; fi

    echo "== tier1: hotpath_micro bench (store hot path + weight arena + pool output boundary + step API + cpu backend + serving saturation + wire transport + paged KV + verifier cost) =="
    MPK_BENCH_STORE_JSON="$ROOT/BENCH_store_hotpath.json" \
    MPK_BENCH_WEIGHT_JSON="$ROOT/BENCH_weight_arena.json" \
    MPK_BENCH_EXEC_INTO_JSON="$ROOT/BENCH_exec_into.json" \
    MPK_BENCH_STEP_JSON="$ROOT/BENCH_step_overhead.json" \
    MPK_BENCH_CPU_JSON="$ROOT/BENCH_cpu_backend.json" \
    MPK_BENCH_SATURATION_JSON="$ROOT/BENCH_saturation.json" \
    MPK_BENCH_TRANSPORT_JSON="$ROOT/BENCH_transport.json" \
    MPK_BENCH_PAGED_JSON="$ROOT/BENCH_paged_kv.json" \
    MPK_BENCH_VERIFY_JSON="$ROOT/BENCH_verify.json" \
        cargo bench --bench hotpath_micro ||
        echo "tier1: bench skipped (non-fatal)" >&2
    if [[ -f "$ROOT/BENCH_store_hotpath.json" ]]; then cat "$ROOT/BENCH_store_hotpath.json"; fi
    if [[ -f "$ROOT/BENCH_weight_arena.json" ]]; then cat "$ROOT/BENCH_weight_arena.json"; fi
    if [[ -f "$ROOT/BENCH_exec_into.json" ]]; then cat "$ROOT/BENCH_exec_into.json"; fi
    if [[ -f "$ROOT/BENCH_step_overhead.json" ]]; then cat "$ROOT/BENCH_step_overhead.json"; fi
    if [[ -f "$ROOT/BENCH_cpu_backend.json" ]]; then cat "$ROOT/BENCH_cpu_backend.json"; fi
    if [[ -f "$ROOT/BENCH_saturation.json" ]]; then cat "$ROOT/BENCH_saturation.json"; fi
    if [[ -f "$ROOT/BENCH_transport.json" ]]; then cat "$ROOT/BENCH_transport.json"; fi
    if [[ -f "$ROOT/BENCH_paged_kv.json" ]]; then cat "$ROOT/BENCH_paged_kv.json"; fi
    if [[ -f "$ROOT/BENCH_verify.json" ]]; then cat "$ROOT/BENCH_verify.json"; fi
fi

echo "tier1: OK"
