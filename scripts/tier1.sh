#!/usr/bin/env bash
# Tier-1 gate for the rust_pallas crate: release build, test suite, and
# clippy with warnings denied; an optional miri pass over the tensor
# arena (the one module holding unsafe — skipped with a warning when
# miri is absent); then (best-effort) the perf-trajectory benches so
# BENCH_launch_overhead.json and BENCH_store_hotpath.json track the hot
# paths across PRs (spawn-per-iteration vs persistent runtime;
# locked-clone vs borrowed-view tile reads).
#
# Usage: scripts/tier1.sh [--no-bench]
set -euo pipefail
cd "$(dirname "$0")/.."

if ! command -v cargo >/dev/null 2>&1; then
    echo "tier1: cargo not found on PATH — cannot build/test in this environment" >&2
    echo "tier1: (the repo's CI image bakes in the toolchain; locally: rustup default stable)" >&2
    # A skipped gate must not look like a green gate: exit nonzero
    # unless the caller explicitly acknowledges the missing toolchain.
    if [[ "${MPK_ALLOW_MISSING_TOOLCHAIN:-0}" == "1" ]]; then
        echo "tier1: SKIPPED (MPK_ALLOW_MISSING_TOOLCHAIN=1)" >&2
        exit 0
    fi
    exit 2
fi

echo "== tier1: cargo build --release =="
cargo build --release

echo "== tier1: cargo test -q =="
cargo test -q

echo "== tier1: cargo clippy -- -D warnings =="
cargo clippy --all-targets -- -D warnings

# The tensor arena (rust/src/exec/store.rs) is the one module holding
# unsafe; when miri is installed, run it under the interpreter to check
# the aliasing contract (UB detection). Like the missing-cargo path
# above, absence is a loud skip, not a silent green.
if cargo miri --version >/dev/null 2>&1; then
    echo "== tier1: cargo miri test (arena aliasing contract) =="
    cargo miri test --lib exec::store
else
    echo "tier1: miri not installed — skipping arena aliasing gate (rustup component add miri)" >&2
fi

if [[ "${1:-}" != "--no-bench" ]]; then
    echo "== tier1: launch_overhead bench (perf trajectory) =="
    # The benches are plain main() binaries (criterion unavailable
    # offline); each writes its JSON record to the repo root via the
    # MPK_BENCH_*JSON env vars.
    MPK_BENCH_JSON="$PWD/BENCH_launch_overhead.json" \
        cargo bench --bench launch_overhead ||
        echo "tier1: bench skipped (non-fatal)" >&2
    # `if` (not `&&`) so a missing bench file cannot trip errexit.
    if [[ -f BENCH_launch_overhead.json ]]; then cat BENCH_launch_overhead.json; fi

    echo "== tier1: hotpath_micro bench (store hot path) =="
    MPK_BENCH_STORE_JSON="$PWD/BENCH_store_hotpath.json" \
        cargo bench --bench hotpath_micro ||
        echo "tier1: bench skipped (non-fatal)" >&2
    if [[ -f BENCH_store_hotpath.json ]]; then cat BENCH_store_hotpath.json; fi
fi

echo "tier1: OK"
