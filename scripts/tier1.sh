#!/usr/bin/env bash
# Tier-1 gate for the rust_pallas crate: release build, test suite, and
# clippy with warnings denied, then (best-effort) the launch-overhead
# bench so BENCH_launch_overhead.json tracks the perf trajectory across
# PRs (spawn-per-iteration vs persistent runtime).
#
# Usage: scripts/tier1.sh [--no-bench]
set -euo pipefail
cd "$(dirname "$0")/.."

if ! command -v cargo >/dev/null 2>&1; then
    echo "tier1: cargo not found on PATH — cannot build/test in this environment" >&2
    echo "tier1: (the repo's CI image bakes in the toolchain; locally: rustup default stable)" >&2
    # A skipped gate must not look like a green gate: exit nonzero
    # unless the caller explicitly acknowledges the missing toolchain.
    if [[ "${MPK_ALLOW_MISSING_TOOLCHAIN:-0}" == "1" ]]; then
        echo "tier1: SKIPPED (MPK_ALLOW_MISSING_TOOLCHAIN=1)" >&2
        exit 0
    fi
    exit 2
fi

echo "== tier1: cargo build --release =="
cargo build --release

echo "== tier1: cargo test -q =="
cargo test -q

echo "== tier1: cargo clippy -- -D warnings =="
cargo clippy --all-targets -- -D warnings

if [[ "${1:-}" != "--no-bench" ]]; then
    echo "== tier1: launch_overhead bench (perf trajectory) =="
    # The benches are plain main() binaries (criterion unavailable
    # offline); the bench writes BENCH_launch_overhead.json to the repo
    # root via MPK_BENCH_JSON.
    MPK_BENCH_JSON="$PWD/BENCH_launch_overhead.json" \
        cargo bench --bench launch_overhead ||
        echo "tier1: bench skipped (non-fatal)" >&2
    [[ -f BENCH_launch_overhead.json ]] && cat BENCH_launch_overhead.json
fi

echo "tier1: OK"
