"""Shared compile-path helpers: tiny-model config and HLO-text lowering.

HLO *text* (not serialized HloModuleProto) is the interchange format with
the rust runtime: jax >= 0.5 emits protos with 64-bit instruction ids
which xla_extension 0.5.1 (behind the `xla` crate) rejects; the text
parser reassigns ids and round-trips cleanly.
"""

from dataclasses import dataclass

import jax
from jax._src.lib import xla_client as xc


@dataclass(frozen=True)
class TinyConfig:
    """Mirror of rust `ModelConfig::tiny()` — keep in sync."""

    layers: int = 4
    d_model: int = 256
    heads: int = 4
    kv_heads: int = 2
    head_dim: int = 64
    ffn: int = 512
    vocab: int = 512

    @property
    def q_dim(self) -> int:
        return self.heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.kv_heads * self.head_dim


#: padded KV-cache length for the real-numerics serving path. Static
#: shapes + a `cur_len` operand replace dynamic cache growth.
S_MAX = 64

#: batch sizes with specialized tGraphs / artifacts (§6.1: powers of two).
BATCH_SIZES = (1, 2, 4, 8)

#: matmul tile width on the N dimension shared by all linear layers.
TILE_N = 128


def to_hlo_text(lowered) -> str:
    """Convert a jax-lowered computation to XLA HLO text."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_fn(fn, args) -> str:
    """Jit + lower `fn` at the given abstract args, return HLO text."""
    return to_hlo_text(jax.jit(fn).lower(*args))
