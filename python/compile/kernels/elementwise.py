"""Pallas elementwise / normalization kernels (L1): RMSNorm, SwiGLU,
residual add. Each is a single-block kernel — on real hardware these
tiles are sized to one shared-memory page (32 KB, §6.2); in interpret
mode the BlockSpec documents the VMEM footprint."""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref):
    x = x_ref[...]
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = x / jnp.sqrt(var + 1e-6) * w_ref[...]


@jax.jit
def rmsnorm(x, weight):
    """Row-wise RMSNorm: x[M, D], weight[D] -> [M, D]."""
    return pl.pallas_call(
        _rmsnorm_kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, jnp.float32),
        interpret=True,
    )(x, weight)


def _swiglu_kernel(gu_ref, o_ref):
    f = o_ref.shape[-1]
    gate = gu_ref[..., :f]
    up = gu_ref[..., f:]
    o_ref[...] = gate * (1.0 / (1.0 + jnp.exp(-gate))) * up


@jax.jit
def swiglu(gate_up):
    """Packed [gate | up] of width 2F -> silu(gate) * up, width F."""
    m, f2 = gate_up.shape
    return pl.pallas_call(
        _swiglu_kernel,
        out_shape=jax.ShapeDtypeStruct((m, f2 // 2), jnp.float32),
        interpret=True,
    )(gate_up)


def _add_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = a_ref[...] + b_ref[...]


@jax.jit
def add(a, b):
    """Elementwise residual add."""
    return pl.pallas_call(
        _add_kernel,
        out_shape=jax.ShapeDtypeStruct(a.shape, jnp.float32),
        interpret=True,
    )(a, b)
