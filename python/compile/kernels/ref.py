"""Pure-jnp oracles for every Pallas kernel.

These are the correctness ground truth: pytest asserts kernel-vs-ref
allclose, and the rust end-to-end path is validated against the fused
reference decode step built from these.
"""

import jax.lax as lax
import jax.numpy as jnp


def matmul_ref(x, w):
    """x[M, K] @ w[K, N] -> [M, N] in f32."""
    return jnp.dot(x, w, preferred_element_type=jnp.float32)


def rmsnorm_ref(x, weight, eps=1e-6):
    """Row-wise RMS normalization with learned scale."""
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x / jnp.sqrt(var + eps) * weight


def swiglu_ref(gate_up):
    """gate_up[M, 2F] packed as [gate | up] -> silu(gate) * up, [M, F]."""
    f = gate_up.shape[-1] // 2
    gate = gate_up[..., :f]
    up = gate_up[..., f:]
    return gate * (1.0 / (1.0 + jnp.exp(-gate))) * up


def add_ref(a, b):
    """Elementwise residual add."""
    return a + b


def embed_ref(ids, table):
    """ids[B] (i32) gathered from table[V, D]."""
    return jnp.take(table, ids, axis=0)


def attention_decode_ref(q, kcache, vcache, cur_len, heads, kv_heads, head_dim):
    """Single-token GQA decode attention over a padded KV cache.

    q: [1, heads*head_dim] — this step's query row.
    kcache/vcache: [S_MAX, kv_heads*head_dim] — padded caches; positions
        >= cur_len are masked out.
    cur_len: scalar i32, number of valid cache entries (the current
        token's K/V must already be appended).
    Returns [1, heads*head_dim].
    """
    s_max = kcache.shape[0]
    qh = q.reshape(heads, head_dim)
    kh = kcache.reshape(s_max, kv_heads, head_dim)
    vh = vcache.reshape(s_max, kv_heads, head_dim)
    group = heads // kv_heads
    mask = jnp.arange(s_max) < cur_len
    outs = []
    for h in range(heads):
        kv_h = h // group
        scores = jnp.einsum("d,sd->s", qh[h], kh[:, kv_h, :])
        scores = scores / jnp.sqrt(jnp.float32(head_dim))
        scores = jnp.where(mask, scores, -1e30)
        p = jnp.exp(scores - jnp.max(scores))
        p = p / jnp.sum(p)
        outs.append(jnp.einsum("s,sd->d", p, vh[:, kv_h, :]))
    return jnp.concatenate(outs).reshape(1, heads * head_dim)


def moe_gather_gemm_ref(x, route_idx, w_expert, expert):
    """Fused gather-GEMM oracle (§6.4): rows of x routed to `expert`
    (route_idx[B, topk] holds expert ids) participate in the GEMM; all
    other rows contribute zero.

    x: [B, D]; w_expert: [D, F]; returns [B, F].
    """
    sel = jnp.any(route_idx == expert, axis=-1)  # [B]
    xg = jnp.where(sel[:, None], x, 0.0)
    return jnp.dot(xg, w_expert, preferred_element_type=jnp.float32)


def topk_route_ref(x, w_gate, topk):
    """Router: logits -> (top-k expert indices, softmax weights)."""
    logits = jnp.dot(x, w_gate)
    vals, idx = lax.top_k(logits, topk)
    w = jnp.exp(vals - jnp.max(vals, axis=-1, keepdims=True))
    w = w / jnp.sum(w, axis=-1, keepdims=True)
    return idx, w
