"""Pallas GQA decode-attention kernel (L1).

One kernel invocation handles one request's single-token decode step
over a padded KV cache with a `cur_len` mask (the paged-attention shape
contract of the serving path: static S_MAX, dynamic valid length).

GPU→TPU adaptation: the paper's attention tasks are FlashDecoding-style
thread-block programs splitting the KV sequence across warps. Here the
whole padded cache fits one VMEM block (S_MAX=64), so the kernel is a
single-block softmax-attention with masked lanes — the cross-SM split
the paper does per-KV-chunk is instead expressed at the tGraph level
(one task per request).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _attn_kernel(q_ref, k_ref, v_ref, len_ref, o_ref, *, heads, kv_heads, head_dim):
    s_max = k_ref.shape[0]
    group = heads // kv_heads
    q = q_ref[...].reshape(heads, head_dim)
    k = k_ref[...].reshape(s_max, kv_heads, head_dim)
    v = v_ref[...].reshape(s_max, kv_heads, head_dim)
    cur_len = len_ref[0]
    mask = jnp.arange(s_max) < cur_len
    scale = 1.0 / jnp.sqrt(jnp.float32(head_dim))

    # [heads, S]: q_h · k_{h//group}
    kq = jnp.einsum("hd,skd->hsk", q, k)  # [heads, S, kv_heads]
    idx = jnp.arange(heads) // group
    scores = jnp.take_along_axis(kq, idx[:, None, None], axis=2)[..., 0] * scale
    scores = jnp.where(mask[None, :], scores, -1e30)
    p = jnp.exp(scores - jnp.max(scores, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    vg = v[:, idx, :]  # [S, heads, head_dim]
    out = jnp.einsum("hs,shd->hd", p, vg)
    o_ref[...] = out.reshape(1, heads * head_dim)


@functools.partial(jax.jit, static_argnames=("heads", "kv_heads", "head_dim"))
def attention_decode(q, kcache, vcache, cur_len, *, heads, kv_heads, head_dim):
    """q[1, heads*head_dim], caches [S_MAX, kv_heads*head_dim],
    cur_len[1] (i32) -> [1, heads*head_dim]."""
    kernel = functools.partial(
        _attn_kernel, heads=heads, kv_heads=kv_heads, head_dim=head_dim
    )
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((1, heads * head_dim), jnp.float32),
        interpret=True,
    )(q, kcache, vcache, cur_len)
