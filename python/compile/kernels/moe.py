"""Pallas fused gather-GEMM kernel for MoE experts (§6.4).

Conventional MoE implementations gather tokens routed to one expert into
a contiguous buffer before the expert GEMM (up to 11% of MoE time in
SGLang per the paper). MPK fuses the gather into the GEMM's data-loading
phase. The TPU/Pallas analogue: the kernel masks non-routed token rows
to zero while loading the activation block into VMEM — no standalone
gather pass, no extra scheduling point.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gather_gemm_kernel(x_ref, idx_ref, w_ref, o_ref, *, expert):
    # fused gather: mask rows not routed to this expert during load.
    sel = jnp.any(idx_ref[...] == expert, axis=-1)  # [B]
    x = jnp.where(sel[:, None], x_ref[...], 0.0)
    o_ref[...] = jnp.dot(x, w_ref[...], preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("expert",))
def moe_gather_gemm(x, route_idx, w_expert, *, expert):
    """x[B, D], route_idx[B, topk] (i32), w_expert[D, F] -> [B, F].

    Rows of x whose route set contains `expert` pass through the GEMM;
    remaining rows yield zeros (weighted combine handles the rest).
    """
    b, _ = x.shape
    f = w_expert.shape[1]
    kernel = functools.partial(_gather_gemm_kernel, expert=expert)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((b, f), jnp.float32),
        interpret=True,
    )(x, route_idx, w_expert)
