"""Pallas tiled matmul kernel (L1).

The paper's per-task CUDA code streams weight tiles HBM→shared-memory
while tensor cores consume the previous tile. The TPU adaptation
expresses the same schedule with a Pallas grid over K-slabs: each grid
step loads one (bk × N) weight slab and one (M × bk) activation slab
into VMEM (the BlockSpec is the HBM↔VMEM schedule) and accumulates into
the output block, which stays resident. `interpret=True` everywhere —
real-TPU lowering emits Mosaic custom-calls the CPU PJRT client cannot
execute; structure, not wallclock, is what we optimize here.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mm_kernel(x_ref, w_ref, o_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("block_k",))
def matmul(x, w, block_k=128):
    """x[M, K] @ w[K, N] via a K-slab Pallas pipeline.

    block_k is clamped to K; K must be divisible by the clamped value.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"K mismatch: {x.shape} vs {w.shape}"
    bk = min(block_k, k)
    assert k % bk == 0, f"K={k} not divisible by block_k={bk}"
    nk = k // bk
    return pl.pallas_call(
        _mm_kernel,
        grid=(nk,),
        in_specs=[
            pl.BlockSpec((m, bk), lambda i: (0, i)),
            pl.BlockSpec((bk, n), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((m, n), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, w)
