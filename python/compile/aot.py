"""AOT lowering: emit every artifact the rust runtime loads.

Per batch size b ∈ {1,2,4,8} (per-batch-size specialized graphs, §6.1):
  matmul_b{b}_k{K}_n{TILE_N}  — Pallas tiled matmul for each distinct K
  rmsnorm_b{b}                — Pallas RMSNorm, D = d_model
  swiglu_b{b}                 — Pallas SwiGLU, 2F -> F
  add_b{b}                    — residual add, width d_model
  embed_b{b}                  — embedding gather
  ref_decode_b{b}             — the fused reference decode step (oracle)
plus once:
  attn_q1                     — per-request decode attention (padded
                                S_MAX cache + cur_len mask)
  moe_gather_gemm_b8          — fused gather-GEMM demo kernel

Everything is written as HLO *text* (see common.to_hlo_text) plus a
manifest.json the rust manifest loader parses.

Usage: cd python && python -m compile.aot --out ../artifacts
"""

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp

from .common import BATCH_SIZES, S_MAX, TILE_N, TinyConfig, lower_fn
from .kernels import attention, elementwise, matmul, moe
from . import model as model_mod

F32 = jnp.float32
I32 = jnp.int32


def spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


def manifest_entry(name, fname, in_specs, n_outputs):
    return {
        "name": name,
        "file": fname,
        "inputs": [
            {"shape": list(s.shape), "dtype": "i32" if s.dtype == jnp.int32 else "f32"}
            for s in in_specs
        ],
        "outputs": n_outputs,
    }


def emit(outdir, name, fn, in_specs, n_outputs, entries, force=False):
    fname = f"{name}.hlo.txt"
    path = os.path.join(outdir, fname)
    if force or not os.path.exists(path):
        text = lower_fn(fn, in_specs)
        with open(path, "w") as f:
            f.write(text)
        print(f"  wrote {fname} ({len(text)} chars)")
    else:
        print(f"  kept  {fname}")
    entries.append(manifest_entry(name, fname, in_specs, n_outputs))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--force", action="store_true", help="re-lower even if files exist")
    args = ap.parse_args()
    outdir = args.out
    os.makedirs(outdir, exist_ok=True)
    cfg = TinyConfig()
    entries = []

    d, q_dim, kv_dim, f = cfg.d_model, cfg.q_dim, cfg.kv_dim, cfg.ffn
    k_values = sorted({d, f})  # contraction dims used by the tiny model

    for b in BATCH_SIZES:
        print(f"batch {b}:")
        for k in k_values:
            emit(
                outdir,
                f"matmul_b{b}_k{k}_n{TILE_N}",
                lambda x, w: (matmul.matmul(x, w),),
                [spec((b, k)), spec((k, TILE_N))],
                1,
                entries,
                args.force,
            )
        emit(
            outdir,
            f"rmsnorm_b{b}",
            lambda x, w: (elementwise.rmsnorm(x, w),),
            [spec((b, d)), spec((d,))],
            1,
            entries,
            args.force,
        )
        emit(
            outdir,
            f"swiglu_b{b}",
            lambda gu: (elementwise.swiglu(gu),),
            [spec((b, 2 * f))],
            1,
            entries,
            args.force,
        )
        emit(
            outdir,
            f"add_b{b}",
            lambda a, c: (elementwise.add(a, c),),
            [spec((b, d)), spec((b, d))],
            1,
            entries,
            args.force,
        )
        emit(
            outdir,
            f"embed_b{b}",
            lambda ids, tbl: (jnp.take(tbl, ids, axis=0),),
            [spec((b,), I32), spec((cfg.vocab, d))],
            1,
            entries,
            args.force,
        )
        # fused reference decode step: logits + per-layer new K/V rows.
        emit(
            outdir,
            f"ref_decode_b{b}",
            model_mod.decode_step_flat(cfg, b),
            model_mod.decode_step_shapes(cfg, b),
            1 + 2 * cfg.layers,
            entries,
            args.force,
        )

    print("shared:")
    attn_fn = functools.partial(
        attention.attention_decode,
        heads=cfg.heads,
        kv_heads=cfg.kv_heads,
        head_dim=cfg.head_dim,
    )
    emit(
        outdir,
        "attn_q1",
        lambda q, kc, vc, ln: (attn_fn(q, kc, vc, ln),),
        [spec((1, q_dim)), spec((S_MAX, kv_dim)), spec((S_MAX, kv_dim)), spec((1,), I32)],
        1,
        entries,
        args.force,
    )
    emit(
        outdir,
        "moe_gather_gemm_b8",
        lambda x, idx, w: (moe.moe_gather_gemm(x, idx, w, expert=0),),
        [spec((8, d)), spec((8, 2), I32), spec((d, 128))],
        1,
        entries,
        args.force,
    )

    manifest = {
        "model": {
            "layers": cfg.layers,
            "d_model": d,
            "heads": cfg.heads,
            "kv_heads": cfg.kv_heads,
            "head_dim": cfg.head_dim,
            "ffn": f,
            "vocab": cfg.vocab,
        },
        "s_max": S_MAX,
        "tile_n": TILE_N,
        "batch_sizes": list(BATCH_SIZES),
        "artifacts": entries,
    }
    with open(os.path.join(outdir, "manifest.json"), "w") as fo:
        json.dump(manifest, fo, indent=1)
    print(f"manifest.json: {len(entries)} artifacts")


if __name__ == "__main__":
    main()
