"""L2: the tiny Qwen3-style decode step in JAX, calling the Pallas
kernels (L1). Lowered whole by `aot.py` into the fused *reference*
artifact the rust end-to-end path validates against, and per task type
into the tile artifacts the megakernel workers execute.

Weight layout (per layer l): ln1, wqkv[D, q+2kv], wo[q, D], ln2,
w_gate_up[D, 2F], w_down[F, D]; plus embed table, final norm weight and
lm_head. All weights arrive as function inputs — the rust side
synthesizes them deterministically and feeds the same values to both the
tiled megakernel path and this fused reference.
"""

import jax.numpy as jnp

from .common import S_MAX, TinyConfig
from .kernels import attention, elementwise, matmul


def layer_weights(cfg: TinyConfig):
    """Abstract shapes of one layer's weight tuple, in order."""
    d, q, kv, f = cfg.d_model, cfg.q_dim, cfg.kv_dim, cfg.ffn
    return [
        ("ln1", (d,)),
        ("wqkv", (d, q + 2 * kv)),
        ("wo", (q, d)),
        ("ln2", (d,)),
        ("w_gate_up", (d, 2 * f)),
        ("w_down", (f, d)),
    ]


def decode_step(cfg: TinyConfig, ids, kcaches, vcaches, cur_len, *weights):
    """One decode iteration for a batch of single tokens.

    ids: [B] i32 token ids.
    kcaches/vcaches: per layer, [B, S_MAX, kv_dim] padded caches.
    cur_len: [1] i32 — valid cache length *excluding* this step's token.
    weights: embed_table, (6 per layer...), final_norm, lm_head.

    Returns (logits[B, vocab], new_k list, new_v list) where new_k/new_v
    are this step's K/V rows ([B, kv_dim]) for the caller to append.
    """
    b = ids.shape[0]
    d, q_dim, kv_dim = cfg.d_model, cfg.q_dim, cfg.kv_dim
    widx = 0
    embed_table = weights[widx]
    widx += 1

    x = jnp.take(embed_table, ids, axis=0)  # [B, D]
    new_ks, new_vs = [], []
    for layer in range(cfg.layers):
        ln1, wqkv, wo, ln2, wgu, wd = weights[widx : widx + 6]
        widx += 6
        h = elementwise.rmsnorm(x, ln1)
        qkv = matmul.matmul(h, wqkv)
        q = qkv[:, :q_dim]
        k = qkv[:, q_dim : q_dim + kv_dim]
        v = qkv[:, q_dim + kv_dim :]
        new_ks.append(k)
        new_vs.append(v)
        # append into padded caches at position cur_len.
        kc = write_row(kcaches[layer], k, cur_len)
        vc = write_row(vcaches[layer], v, cur_len)
        attn_rows = []
        for r in range(b):
            attn_rows.append(
                attention.attention_decode(
                    q[r : r + 1],
                    kc[r],
                    vc[r],
                    cur_len + 1,
                    heads=cfg.heads,
                    kv_heads=cfg.kv_heads,
                    head_dim=cfg.head_dim,
                )
            )
        attn = jnp.concatenate(attn_rows, axis=0)
        attn_out = matmul.matmul(attn, wo)
        x = elementwise.add(x, attn_out)
        h2 = elementwise.rmsnorm(x, ln2)
        gu = matmul.matmul(h2, wgu)
        act = elementwise.swiglu(gu)
        down = matmul.matmul(act, wd)
        x = elementwise.add(x, down)

    final_norm, lm_head = weights[widx], weights[widx + 1]
    xf = elementwise.rmsnorm(x, final_norm)
    logits = matmul.matmul(xf, lm_head)
    return (logits, *new_ks, *new_vs)


def write_row(cache, row, cur_len):
    """cache[B, S_MAX, kv], row[B, kv] -> cache with row at position
    cur_len (dynamic index)."""
    b, s_max, kv = cache.shape
    onehot = (jnp.arange(s_max) == cur_len[0]).astype(cache.dtype)  # [S]
    return cache * (1.0 - onehot)[None, :, None] + onehot[None, :, None] * row[:, None, :]


def decode_step_shapes(cfg: TinyConfig, batch: int):
    """Abstract input signature of `decode_step` for AOT lowering."""
    import jax

    f32 = jnp.float32
    shapes = [
        jax.ShapeDtypeStruct((batch,), jnp.int32),  # ids
    ]
    for _ in range(cfg.layers):
        shapes.append(jax.ShapeDtypeStruct((batch, S_MAX, cfg.kv_dim), f32))
    for _ in range(cfg.layers):
        shapes.append(jax.ShapeDtypeStruct((batch, S_MAX, cfg.kv_dim), f32))
    shapes.append(jax.ShapeDtypeStruct((1,), jnp.int32))  # cur_len
    shapes.append(jax.ShapeDtypeStruct((cfg.vocab, cfg.d_model), f32))  # embed
    for _ in range(cfg.layers):
        for _, shp in layer_weights(cfg):
            shapes.append(jax.ShapeDtypeStruct(shp, f32))
    shapes.append(jax.ShapeDtypeStruct((cfg.d_model,), f32))  # final norm
    shapes.append(jax.ShapeDtypeStruct((cfg.d_model, cfg.vocab), f32))  # lm head
    return shapes


def decode_step_flat(cfg: TinyConfig, batch: int):
    """Wrap `decode_step` with a flat positional signature matching
    `decode_step_shapes` (ids, k caches…, v caches…, cur_len, weights…)."""

    def fn(*args):
        ids = args[0]
        kcaches = list(args[1 : 1 + cfg.layers])
        vcaches = list(args[1 + cfg.layers : 1 + 2 * cfg.layers])
        cur_len = args[1 + 2 * cfg.layers]
        weights = args[2 + 2 * cfg.layers :]
        return decode_step(cfg, ids, kcaches, vcaches, cur_len, *weights)

    return fn
