"""AOT manifest integrity: the contract between aot.py and the rust
manifest loader. Runs only when artifacts have been built (cheap check,
no re-lowering)."""

import json
import os

import pytest

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def manifest():
    path = os.path.join(ART, "manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built (run `make artifacts`)")
    with open(path) as f:
        return json.load(f)


def test_manifest_model_matches_tiny_config():
    from compile.common import TinyConfig

    m = manifest()["model"]
    cfg = TinyConfig()
    assert m["layers"] == cfg.layers
    assert m["d_model"] == cfg.d_model
    assert m["heads"] == cfg.heads
    assert m["kv_heads"] == cfg.kv_heads
    assert m["ffn"] == cfg.ffn
    assert m["vocab"] == cfg.vocab


def test_every_artifact_file_exists_and_is_hlo_text():
    man = manifest()
    assert len(man["artifacts"]) >= 25
    for a in man["artifacts"]:
        path = os.path.join(ART, a["file"])
        assert os.path.exists(path), a["name"]
        head = open(path).read(200)
        assert "HloModule" in head, f"{a['name']} is not HLO text"


def test_batch_specializations_complete():
    man = manifest()
    names = {a["name"] for a in man["artifacts"]}
    for b in man["batch_sizes"]:
        for stem in [f"matmul_b{b}_k256_n128", f"rmsnorm_b{b}", f"swiglu_b{b}",
                     f"add_b{b}", f"embed_b{b}", f"ref_decode_b{b}"]:
            assert stem in names, stem
    assert "attn_q1" in names
    assert "moe_gather_gemm_b8" in names


def test_ref_decode_signature():
    man = manifest()
    cfg = man["model"]
    ref = next(a for a in man["artifacts"] if a["name"] == "ref_decode_b1")
    layers = cfg["layers"]
    # ids + 2L caches + cur_len + embed + 6L weights + final + lm_head
    assert len(ref["inputs"]) == 1 + 2 * layers + 1 + 1 + 6 * layers + 2
    assert ref["outputs"] == 1 + 2 * layers
    assert ref["inputs"][0]["dtype"] == "i32"
