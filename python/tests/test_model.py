"""L2 model-level tests: decode_step shape contract, KV append
semantics, and a 2-step decode consistency check (the cache written at
step t is what attention reads at step t+1)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as model_mod
from compile.common import S_MAX, TinyConfig
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")
CFG = TinyConfig()


def make_weights(cfg: TinyConfig, seed=0):
    rng = np.random.default_rng(seed)

    def r(shape, scale):
        return jnp.asarray(rng.standard_normal(shape, dtype=np.float32) * scale)

    ws = [r((cfg.vocab, cfg.d_model), 0.05)]
    for _ in range(cfg.layers):
        for name, shp in model_mod.layer_weights(cfg):
            scale = 1.0 if name.startswith("ln") else 0.05
            ws.append(r(shp, scale) if not name.startswith("ln") else jnp.ones(shp))
    ws.append(jnp.ones((cfg.d_model,)))
    ws.append(r((cfg.d_model, cfg.vocab), 0.05))
    return ws


def empty_caches(cfg: TinyConfig, b: int):
    kc = [jnp.zeros((b, S_MAX, cfg.kv_dim)) for _ in range(cfg.layers)]
    vc = [jnp.zeros((b, S_MAX, cfg.kv_dim)) for _ in range(cfg.layers)]
    return kc, vc


@pytest.mark.parametrize("b", [1, 2, 4])
def test_decode_step_shapes(b):
    ws = make_weights(CFG)
    kc, vc = empty_caches(CFG, b)
    ids = jnp.arange(b, dtype=jnp.int32)
    out = model_mod.decode_step(CFG, ids, kc, vc, jnp.asarray([0], jnp.int32), *ws)
    logits = out[0]
    assert logits.shape == (b, CFG.vocab)
    assert len(out) == 1 + 2 * CFG.layers
    for nk in out[1 : 1 + CFG.layers]:
        assert nk.shape == (b, CFG.kv_dim)


def test_write_row_places_at_cur_len():
    cache = jnp.zeros((2, S_MAX, 4))
    row = jnp.ones((2, 4)) * 7.0
    out = model_mod.write_row(cache, row, jnp.asarray([5], jnp.int32))
    np.testing.assert_allclose(out[:, 5, :], row)
    np.testing.assert_allclose(out[:, 4, :], 0.0)
    np.testing.assert_allclose(out[:, 6, :], 0.0)


def test_write_row_preserves_existing():
    cache = jnp.ones((1, S_MAX, 4)) * 3.0
    row = jnp.zeros((1, 4))
    out = model_mod.write_row(cache, row, jnp.asarray([2], jnp.int32))
    np.testing.assert_allclose(out[0, 1, :], 3.0)
    np.testing.assert_allclose(out[0, 2, :], 0.0)


def test_two_step_decode_uses_appended_kv():
    """Step 1's K/V must influence step 2's logits: running step 2 with
    and without step 1's rows appended must differ."""
    b = 1
    ws = make_weights(CFG, seed=3)
    kc, vc = empty_caches(CFG, b)
    ids0 = jnp.asarray([5], jnp.int32)
    out0 = model_mod.decode_step(CFG, ids0, kc, vc, jnp.asarray([0], jnp.int32), *ws)
    new_ks = out0[1 : 1 + CFG.layers]
    new_vs = out0[1 + CFG.layers :]
    # append step-0 KV at position 0.
    kc1 = [model_mod.write_row(kc[l], new_ks[l], jnp.asarray([0], jnp.int32)) for l in range(CFG.layers)]
    vc1 = [model_mod.write_row(vc[l], new_vs[l], jnp.asarray([0], jnp.int32)) for l in range(CFG.layers)]
    ids1 = jnp.asarray([7], jnp.int32)
    with_history = model_mod.decode_step(CFG, ids1, kc1, vc1, jnp.asarray([1], jnp.int32), *ws)[0]
    without_history = model_mod.decode_step(CFG, ids1, kc, vc, jnp.asarray([1], jnp.int32), *ws)[0]
    assert not np.allclose(np.asarray(with_history), np.asarray(without_history)), (
        "history K/V had no effect — cache append is broken"
    )


def test_decode_matches_manual_composition():
    """decode_step == manual layer-by-layer composition from the refs."""
    b = 2
    cfg = CFG
    ws = make_weights(cfg, seed=9)
    kc, vc = empty_caches(cfg, b)
    ids = jnp.asarray([3, 100], jnp.int32)
    cur = jnp.asarray([0], jnp.int32)
    got = model_mod.decode_step(cfg, ids, kc, vc, cur, *ws)[0]

    widx = 0
    x = ref.embed_ref(ids, ws[widx]); widx += 1
    for _ in range(cfg.layers):
        ln1, wqkv, wo, ln2, wgu, wd = ws[widx : widx + 6]; widx += 6
        h = ref.rmsnorm_ref(x, ln1)
        qkv = ref.matmul_ref(h, wqkv)
        q = qkv[:, : cfg.q_dim]
        k = qkv[:, cfg.q_dim : cfg.q_dim + cfg.kv_dim]
        v = qkv[:, cfg.q_dim + cfg.kv_dim :]
        rows = []
        for r in range(b):
            kcr = jnp.zeros((S_MAX, cfg.kv_dim)).at[0].set(k[r])
            vcr = jnp.zeros((S_MAX, cfg.kv_dim)).at[0].set(v[r])
            rows.append(
                ref.attention_decode_ref(
                    q[r : r + 1], kcr, vcr, jnp.int32(1), cfg.heads, cfg.kv_heads, cfg.head_dim
                )
            )
        attn = jnp.concatenate(rows, axis=0)
        x = ref.add_ref(x, ref.matmul_ref(attn, wo))
        h2 = ref.rmsnorm_ref(x, ln2)
        act = ref.swiglu_ref(ref.matmul_ref(h2, wgu))
        x = ref.add_ref(x, ref.matmul_ref(act, wd))
    xf = ref.rmsnorm_ref(x, ws[widx]); widx += 1
    want = ref.matmul_ref(xf, ws[widx])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-3, atol=1e-4)
