"""Kernel-vs-oracle correctness: every Pallas kernel against its pure-jnp
reference, including hypothesis sweeps over shapes and values. This is
the L1 correctness signal the whole stack rests on."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention, elementwise, matmul, moe, ref

jax.config.update("jax_platform_name", "cpu")


def rand(shape, seed, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape, dtype=np.float32) * scale)


# ---------------------------------------------------------------- matmul
@pytest.mark.parametrize("m,k,n", [(1, 256, 128), (8, 512, 128), (4, 256, 512), (2, 128, 64)])
def test_matmul_matches_ref(m, k, n):
    x, w = rand((m, k), 1), rand((k, n), 2)
    np.testing.assert_allclose(
        matmul.matmul(x, w), ref.matmul_ref(x, w), rtol=1e-5, atol=1e-5
    )


def test_matmul_single_k_slab():
    x, w = rand((2, 64), 3), rand((64, 32), 4)
    np.testing.assert_allclose(
        matmul.matmul(x, w, block_k=256), ref.matmul_ref(x, w), rtol=1e-5, atol=1e-5
    )


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(1, 8),
    kblk=st.integers(1, 4),
    n=st.sampled_from([16, 32, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_hypothesis_shapes(m, kblk, n, seed):
    k = kblk * 128
    x, w = rand((m, k), seed), rand((k, n), seed + 1)
    np.testing.assert_allclose(
        matmul.matmul(x, w), ref.matmul_ref(x, w), rtol=1e-4, atol=1e-4
    )


def test_matmul_rejects_bad_k():
    with pytest.raises(AssertionError):
        matmul.matmul(rand((2, 100), 0), rand((100, 16), 1), block_k=64)


# ----------------------------------------------------------- elementwise
@pytest.mark.parametrize("m,d", [(1, 256), (8, 256), (3, 64)])
def test_rmsnorm_matches_ref(m, d):
    x, w = rand((m, d), 5), rand((d,), 6)
    np.testing.assert_allclose(
        elementwise.rmsnorm(x, w), ref.rmsnorm_ref(x, w), rtol=1e-5, atol=1e-5
    )


def test_rmsnorm_scale_invariance():
    # RMSNorm(a·x) == RMSNorm(x) for a > 0 (up to eps effects).
    x, w = rand((4, 256), 7), rand((256,), 8)
    a = elementwise.rmsnorm(x, w)
    b = elementwise.rmsnorm(x * 1000.0, w)
    np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(m=st.integers(1, 8), f=st.sampled_from([64, 256, 512]), seed=st.integers(0, 2**31 - 1))
def test_swiglu_hypothesis(m, f, seed):
    gu = rand((m, 2 * f), seed)
    np.testing.assert_allclose(
        elementwise.swiglu(gu), ref.swiglu_ref(gu), rtol=1e-5, atol=1e-5
    )


def test_swiglu_zero_gate_is_zero():
    gu = jnp.concatenate([jnp.zeros((2, 64)), rand((2, 64), 9)], axis=-1)
    np.testing.assert_allclose(elementwise.swiglu(gu), jnp.zeros((2, 64)), atol=1e-7)


def test_add_matches_ref():
    a, b = rand((4, 256), 10), rand((4, 256), 11)
    np.testing.assert_allclose(elementwise.add(a, b), a + b, rtol=1e-6)


# ------------------------------------------------------------- attention
def attn_pair(seed, cur_len, heads=4, kv_heads=2, head_dim=64, s_max=64):
    q = rand((1, heads * head_dim), seed)
    kc = rand((s_max, kv_heads * head_dim), seed + 1)
    vc = rand((s_max, kv_heads * head_dim), seed + 2)
    ln = jnp.asarray([cur_len], dtype=jnp.int32)
    got = attention.attention_decode(
        q, kc, vc, ln, heads=heads, kv_heads=kv_heads, head_dim=head_dim
    )
    want = ref.attention_decode_ref(q, kc, vc, ln[0], heads, kv_heads, head_dim)
    return got, want


@pytest.mark.parametrize("cur_len", [1, 2, 17, 63, 64])
def test_attention_matches_ref(cur_len):
    got, want = attn_pair(20, cur_len)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(cur_len=st.integers(1, 64), seed=st.integers(0, 2**31 - 1))
def test_attention_hypothesis(cur_len, seed):
    got, want = attn_pair(seed, cur_len)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_attention_mask_excludes_padding():
    # poisoning masked cache positions must not change the output.
    heads, kv_heads, head_dim, s_max = 4, 2, 64, 64
    q = rand((1, heads * head_dim), 30)
    kc = rand((s_max, kv_heads * head_dim), 31)
    vc = rand((s_max, kv_heads * head_dim), 32)
    ln = jnp.asarray([10], dtype=jnp.int32)
    base = attention.attention_decode(q, kc, vc, ln, heads=heads, kv_heads=kv_heads, head_dim=head_dim)
    kc2 = kc.at[10:].set(1e6)
    vc2 = vc.at[10:].set(-1e6)
    poisoned = attention.attention_decode(q, kc2, vc2, ln, heads=heads, kv_heads=kv_heads, head_dim=head_dim)
    np.testing.assert_allclose(base, poisoned, rtol=1e-5)


def test_attention_single_valid_token_returns_its_value():
    # with one valid cache entry, softmax weight is 1 on it.
    heads, kv_heads, head_dim, s_max = 4, 2, 64, 64
    q = rand((1, heads * head_dim), 33)
    kc = rand((s_max, kv_heads * head_dim), 34)
    vc = rand((s_max, kv_heads * head_dim), 35)
    ln = jnp.asarray([1], dtype=jnp.int32)
    out = attention.attention_decode(q, kc, vc, ln, heads=heads, kv_heads=kv_heads, head_dim=head_dim)
    group = heads // kv_heads
    want = jnp.concatenate(
        [vc[0].reshape(kv_heads, head_dim)[h // group] for h in range(heads)]
    ).reshape(1, -1)
    np.testing.assert_allclose(out, want, rtol=1e-5)


# ------------------------------------------------------------------ moe
@settings(max_examples=10, deadline=None)
@given(b=st.integers(1, 8), expert=st.integers(0, 3), seed=st.integers(0, 2**31 - 1))
def test_moe_gather_gemm_hypothesis(b, expert, seed):
    rng = np.random.default_rng(seed)
    x = rand((b, 64), seed)
    idx = jnp.asarray(rng.integers(0, 4, size=(b, 2)), dtype=jnp.int32)
    w = rand((64, 32), seed + 1)
    got = moe.moe_gather_gemm(x, idx, w, expert=expert)
    want = ref.moe_gather_gemm_ref(x, idx, w, expert)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_moe_unrouted_rows_are_zero():
    x = rand((4, 64), 40)
    idx = jnp.zeros((4, 2), dtype=jnp.int32)  # everyone routed to expert 0
    w = rand((64, 32), 41)
    out = moe.moe_gather_gemm(x, idx, w, expert=3)
    np.testing.assert_allclose(out, jnp.zeros((4, 32)), atol=1e-7)


def test_topk_route_weights_sum_to_one():
    x = rand((8, 64), 42)
    wg = rand((64, 16), 43)
    idx, w = ref.topk_route_ref(x, wg, 4)
    assert idx.shape == (8, 4)
    np.testing.assert_allclose(np.sum(np.asarray(w), axis=-1), np.ones(8), rtol=1e-5)
